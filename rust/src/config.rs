//! Run configuration: the paper's hyper-parameters (§V-F) plus engine
//! knobs, loadable from a TOML-subset file and overridable from the CLI.
//!
//! The TOML reader supports the subset real configs use — `key = value`
//! pairs, `[section]` headers, strings, ints, floats, bools, comments —
//! which covers every config this project ships (the full TOML crate is
//! unavailable offline).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Which engine executes the dense numeric step of Revolver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Pure-Rust scoring + LA update (default; the paper's C/C++ analog).
    Native,
    /// Batched scoring + LA update through the AOT-compiled XLA
    /// artifact via PJRT (L1/L2 integration).
    Xla,
}

impl std::str::FromStr for Engine {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "native" => Ok(Engine::Native),
            "xla" => Ok(Engine::Xla),
            other => bail!("unknown engine {other:?} (expected native|xla)"),
        }
    }
}

/// Execution model for Revolver (the paper implements both, §V-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutionModel {
    /// Free-running workers over shared state (the paper's headline
    /// C/C++ implementation).
    Asynchronous,
    /// BSP with per-step barriers and frozen label snapshots (the
    /// Giraph-style variant; ablation E4).
    Synchronous,
}

/// How the engine splits vertices across worker threads (DESIGN.md
/// §Scheduler).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Schedule {
    /// Contiguous ~|V|/n chunks — the paper's layout (default).
    #[default]
    Vertex,
    /// Contiguous chunks balanced by cumulative out-degree, so a
    /// power-law hub chunk no longer serializes the step barrier.
    Degree,
}

impl std::str::FromStr for Schedule {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "vertex" => Ok(Schedule::Vertex),
            "degree" => Ok(Schedule::Degree),
            other => bail!("unknown schedule {other:?} (expected vertex|degree)"),
        }
    }
}

/// Active-set (frontier-driven) execution of the superstep engine
/// (DESIGN.md §Active-set). `On` skips vertices whose neighbourhood has
/// not changed since their last evaluation — late supersteps cost
/// ~|frontier| instead of ~|V| — and halts immediately when the
/// frontier empties. `Off` is the escape hatch that re-evaluates every
/// vertex every step, bit-identical to the legacy engine at
/// `threads = 1` and the same seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Frontier {
    /// Frontier-driven supersteps (default for `run`/`refine`).
    #[default]
    On,
    /// Legacy full-sweep supersteps (bit-exact reproduction mode).
    Off,
}

impl std::str::FromStr for Frontier {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "on" | "true" | "1" => Ok(Frontier::On),
            "off" | "false" | "0" => Ok(Frontier::Off),
            other => bail!("unknown frontier mode {other:?} (expected on|off)"),
        }
    }
}

/// Storage format of the learning-automaton probability slab
/// (`partitioners::revolver::ProbSlab`, the n×k hot structure).
///
/// Rows are normalized probability vectors, so 16-bit fixed point
/// (q = round(p·65535)) resolves 1/65535 ≈ 1.5e-5 per entry — far below
/// the statistical noise of the roulette selection — while halving the
/// slab's load/store bandwidth. `F32` is the bit-exact reproduction
/// format the parity tests pin.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ProbFormat {
    /// u16 fixed point, 1/65535 resolution (default; 2× less bandwidth).
    #[default]
    Q16,
    /// f32 rows — bit-exact with the pre-quantization implementation.
    F32,
}

impl std::str::FromStr for ProbFormat {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "q16" | "u16" | "fixed" => Ok(ProbFormat::Q16),
            "f32" | "float" => Ok(ProbFormat::F32),
            other => bail!("unknown prob format {other:?} (expected q16|f32)"),
        }
    }
}

/// Streaming algorithm family (L4 `stream` subsystem): one-pass linear
/// deterministic greedy, one-pass Fennel, or prioritized restreaming.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamAlgo {
    /// Linear deterministic greedy (Stanton & Kliot, KDD'12).
    Ldg,
    /// Degree-penalized greedy (Tsourakakis et al., WSDM'14).
    Fennel,
    /// N prioritized restreaming passes over the Fennel objective
    /// (Awadelkarim & Ugander, KDD'20).
    Restream,
}

impl StreamAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            StreamAlgo::Ldg => "ldg",
            StreamAlgo::Fennel => "fennel",
            StreamAlgo::Restream => "restream",
        }
    }
}

impl std::str::FromStr for StreamAlgo {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "ldg" => Ok(StreamAlgo::Ldg),
            "fennel" => Ok(StreamAlgo::Fennel),
            "restream" => Ok(StreamAlgo::Restream),
            other => bail!("unknown stream algorithm {other:?} (expected ldg|fennel|restream)"),
        }
    }
}

/// Order in which a streaming pass visits vertices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StreamOrder {
    /// Vertex-id order (the order edge-list files are written in).
    #[default]
    Natural,
    /// Uniform random permutation (seeded from the run seed).
    Shuffled,
    /// Breadth-first from vertex 0, restarting at the next unvisited
    /// vertex per component — neighbours arrive near each other.
    Bfs,
}

impl std::str::FromStr for StreamOrder {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "natural" => Ok(StreamOrder::Natural),
            "shuffled" | "random" => Ok(StreamOrder::Shuffled),
            "bfs" => Ok(StreamOrder::Bfs),
            other => bail!("unknown stream order {other:?} (expected natural|shuffled|bfs)"),
        }
    }
}

/// Greedy objective the dynamic subsystem scores arriving vertices
/// with ([`crate::dynamic::IncrementalPartitioner`]): the same LDG /
/// Fennel scoring rules the streaming passes use, applied against the
/// *full current assignment* (Prioritized Restreaming's observation:
/// an arriving vertex is best placed against everything already
/// placed, not a prefix).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// Linear deterministic greedy score.
    Ldg,
    /// Degree-penalized greedy score (γ from `fennel_gamma`); the
    /// default — restreaming placement is Fennel-objective.
    #[default]
    Fennel,
}

impl std::str::FromStr for Placement {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "ldg" => Ok(Placement::Ldg),
            "fennel" => Ok(Placement::Fennel),
            other => bail!("unknown placement {other:?} (expected ldg|fennel)"),
        }
    }
}

/// CLI progress verbosity (`--verbosity`): how chatty the stderr
/// progress lines routed through [`crate::obs::log`] are. Hard errors
/// always print regardless of level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Verbosity {
    /// No progress output (long scripted runs).
    Quiet,
    /// One-line progress per phase — what the CLI always printed.
    #[default]
    Info,
    /// Additional detail lines.
    Debug,
}

impl std::str::FromStr for Verbosity {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "quiet" => Ok(Verbosity::Quiet),
            "info" => Ok(Verbosity::Info),
            "debug" => Ok(Verbosity::Debug),
            other => bail!("unknown verbosity {other:?} (expected quiet|info|debug)"),
        }
    }
}

/// Ingest strictness of the text readers (`--ingest`): edge lists,
/// update logs, and the streaming file adapter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IngestMode {
    /// The first malformed line aborts the load with a
    /// path/line/snippet diagnostic (the safe default).
    #[default]
    Strict,
    /// Malformed lines are skipped and counted
    /// (`ingest_skipped_lines`), each logged with its path, 1-based
    /// line number and a truncated snippet — for dirty real-world
    /// dumps where one torn line should not kill an hours-long run.
    Lenient,
}

impl std::str::FromStr for IngestMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "strict" => Ok(IngestMode::Strict),
            "lenient" => Ok(IngestMode::Lenient),
            other => bail!("unknown ingest mode {other:?} (expected strict|lenient)"),
        }
    }
}

/// Initial assignment policy for the iterative partitioners
/// (Revolver / Spinner).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Init {
    /// Uniform-random labels, uniform LA probabilities (the paper).
    #[default]
    Random,
    /// Warm start: labels from a streaming pass; Revolver additionally
    /// biases each vertex's LA probability row toward the streamed
    /// label.
    Stream(StreamAlgo),
}

impl std::str::FromStr for Init {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        let low = s.to_lowercase();
        if low == "random" {
            return Ok(Init::Random);
        }
        match low.strip_prefix("stream:") {
            Some(algo) => Ok(Init::Stream(algo.parse()?)),
            None => bail!("unknown init {s:?} (expected random|stream:<ldg|fennel|restream>)"),
        }
    }
}

/// All knobs of a Revolver/Spinner run. Defaults are the paper's §V-F
/// settings.
#[derive(Debug, Clone)]
pub struct RevolverConfig {
    /// Number of partitions k.
    pub parts: usize,
    /// Imbalance ratio ε (capacity C = (1+ε)|E|/k).
    pub epsilon: f64,
    /// Maximum number of steps (paper: 290).
    pub max_steps: u32,
    /// Consecutive low-improvement steps before halting (paper: 5).
    pub halt_window: u32,
    /// Minimum score improvement θ (paper: 0.001).
    pub halt_theta: f64,
    /// LA reward rate α (paper: 1).
    pub alpha: f32,
    /// LA penalty rate β (paper: 0.1).
    pub beta: f32,
    /// Worker threads (paper: one per core).
    pub threads: usize,
    /// How vertices are split across worker threads.
    pub schedule: Schedule,
    /// Active-set execution: skip vertices whose neighbourhood has not
    /// changed since their last evaluation (`--frontier off` restores
    /// the legacy full-sweep supersteps bit-exactly).
    pub frontier: Frontier,
    /// Frontier collection strategy crossover: while the frontier holds
    /// more than this fraction of |V|, the coordinator scans the stamp
    /// array (dense, branch-free); once it shrinks below, workers record
    /// woken vertices into per-worker worklists merged at the step
    /// barrier, making coordinator cost O(frontier) instead of O(n).
    /// `0.0` forces scan-always, `1.0` worklist-always (both produce
    /// bit-identical runs; DESIGN.md §Hot paths).
    pub frontier_dense_frac: f64,
    /// Storage format of the LA probability slab (`q16` fixed point
    /// halves bandwidth; `f32` is the bit-exact parity format).
    pub prob_format: ProbFormat,
    /// RNG seed.
    pub seed: u64,
    /// Async (paper headline) or sync (ablation).
    pub execution: ExecutionModel,
    /// Native Rust or XLA/PJRT numeric engine.
    pub engine: Engine,
    /// Artifacts directory for Engine::Xla.
    pub artifacts_dir: String,
    /// Use the classic (unweighted) LA update — ablation E5.
    pub classic_la: bool,
    /// Record a full quality trace point every `trace_every` steps
    /// (0 = only the final point; 1 = Figure-4 style per-step traces).
    /// Tracing costs an O(|E|) metrics pass per sampled step.
    pub trace_every: u32,
    /// Initial assignment: uniform random (paper) or a streaming
    /// warm start (`--init stream:<algo>`).
    pub init: Init,
    /// Vertex visit order of streaming passes.
    pub stream_order: StreamOrder,
    /// Fennel's load exponent γ (its paper recommends 1.5).
    pub fennel_gamma: f64,
    /// Number of streaming passes for the `restream` partitioner
    /// (pass 1 streams in `stream_order`, later passes in priority
    /// order reusing the previous assignment).
    pub restream_passes: u32,
    /// Multilevel: stop coarsening once the level has at most this many
    /// vertices (the V-cycle raises it to `2·parts` if smaller, so the
    /// coarsest graph always has room for k non-empty partitions).
    pub coarsen_until: usize,
    /// Multilevel: superstep budget of each per-level refinement pass
    /// (convergence halting may stop a level earlier).
    pub refine_steps: u32,
    /// Multilevel: the registered algorithm that partitions the
    /// coarsest graph (any [`crate::partitioners::by_name`] entry except
    /// the multilevel family itself; default the streaming `fennel`).
    pub coarse_algo: String,
    /// Dynamic: auto-compact the [`crate::dynamic::DynamicGraph`]
    /// overlay once its delta adjacency entries exceed this fraction of
    /// the base CSR's edges (bounds delta-query cost between epochs).
    pub compact_ratio: f64,
    /// Dynamic: superstep budget of each epoch's frontier-seeded repair
    /// pass (convergence / empty-frontier halting may stop earlier).
    pub repair_steps: u32,
    /// Dynamic: greedy objective for placing arriving vertices against
    /// the full current assignment.
    pub placement: Placement,
    /// Progress verbosity of the CLI ([`crate::obs::log`]).
    pub verbosity: Verbosity,
    /// Stream JSONL observability events to this file (`--obs-log`);
    /// empty = off. Installs a [`crate::obs::RunRecorder`] for the run.
    pub obs_log: String,
    /// Print the end-of-run hierarchical span timing tree
    /// (`--profile`). Also installs a run recorder.
    pub profile: bool,
    /// Serve live telemetry (`/metrics`, `/healthz`, `/profile`,
    /// `/events`) on this `HOST:PORT` for the run's lifetime
    /// (`--metrics-addr`); empty = off. Port 0 picks a free port — the
    /// bound address is echoed on stderr. Also installs a run recorder.
    pub metrics_addr: String,
    /// Learning-dynamics observatory (`--diag`): per-step migration
    /// flow matrix, per-partition gauges, LA decisiveness and
    /// oscillation probes. Only active while a recorder is installed;
    /// installs one itself when set. Off by default — the probes cost
    /// one labels snapshot per step plus O(|frontier|·k) entropy work.
    pub diag: bool,
    /// Ingest strictness for edge-list / update-log text readers
    /// (`--ingest`): strict aborts on the first malformed line,
    /// lenient skips-and-counts it with a line-numbered diagnostic.
    pub ingest: IngestMode,
    /// Checkpoint directory (`--checkpoint`); empty = checkpointing
    /// off. `partition` writes at step cadence, `dynamic` at epoch
    /// cadence (see [`crate::fault::checkpoint`]).
    pub checkpoint_dir: String,
    /// Write a checkpoint every this many steps (`partition`) or
    /// epochs (`dynamic`); must be >= 1 when checkpointing is on.
    pub checkpoint_every: u32,
    /// Resume from the newest checkpoint in `checkpoint_dir`
    /// (`--resume`); starting fresh when the directory is empty.
    pub resume: bool,
    /// Deterministic fault-injection plan (`--faults`); empty = none.
    pub faults: crate::fault::FaultPlan,
}

impl Default for RevolverConfig {
    fn default() -> Self {
        RevolverConfig {
            parts: 8,
            epsilon: 0.05,
            max_steps: 290,
            halt_window: 5,
            halt_theta: 0.001,
            alpha: 1.0,
            beta: 0.1,
            threads: default_threads(),
            schedule: Schedule::Vertex,
            frontier: Frontier::On,
            frontier_dense_frac: 0.25,
            prob_format: ProbFormat::Q16,
            seed: 42,
            execution: ExecutionModel::Asynchronous,
            engine: Engine::Native,
            artifacts_dir: "artifacts".to_string(),
            classic_la: false,
            trace_every: 0,
            init: Init::Random,
            stream_order: StreamOrder::Natural,
            fennel_gamma: 1.5,
            restream_passes: 3,
            coarsen_until: 256,
            refine_steps: 10,
            coarse_algo: "fennel".to_string(),
            compact_ratio: 0.25,
            repair_steps: 10,
            placement: Placement::Fennel,
            verbosity: Verbosity::Info,
            obs_log: String::new(),
            profile: false,
            metrics_addr: String::new(),
            diag: false,
            ingest: IngestMode::Strict,
            checkpoint_dir: String::new(),
            checkpoint_every: 10,
            resume: false,
            faults: crate::fault::FaultPlan::default(),
        }
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl RevolverConfig {
    /// Validate parameter ranges, including the paper's eq. (2)
    /// non-empty-partition condition `(k−1)·ε << 1` (we enforce the
    /// weak form `(k−1)·ε < k`, i.e. capacity×k covers |E|, and warn
    /// via error only on nonsensical values).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.parts >= 2, "parts must be >= 2, got {}", self.parts);
        anyhow::ensure!(self.epsilon >= 0.0, "epsilon must be >= 0");
        anyhow::ensure!(self.max_steps >= 1, "max_steps must be >= 1");
        anyhow::ensure!(self.halt_window >= 1, "halt_window must be >= 1");
        anyhow::ensure!(self.halt_theta >= 0.0, "halt_theta must be >= 0");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.alpha),
            "alpha must be in [0,1], got {}",
            self.alpha
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.beta),
            "beta must be in [0,1], got {}",
            self.beta
        );
        anyhow::ensure!(self.threads >= 1, "threads must be >= 1");
        anyhow::ensure!(
            self.frontier_dense_frac.is_finite()
                && (0.0..=1.0).contains(&self.frontier_dense_frac),
            "frontier_dense_frac must be in [0,1], got {}",
            self.frontier_dense_frac
        );
        anyhow::ensure!(
            self.fennel_gamma > 1.0,
            "fennel_gamma must be > 1 (superlinear load cost), got {}",
            self.fennel_gamma
        );
        anyhow::ensure!(self.restream_passes >= 1, "restream_passes must be >= 1");
        anyhow::ensure!(self.coarsen_until >= 2, "coarsen_until must be >= 2");
        anyhow::ensure!(self.refine_steps >= 1, "refine_steps must be >= 1");
        anyhow::ensure!(
            self.compact_ratio.is_finite() && self.compact_ratio > 0.0,
            "compact_ratio must be a positive finite fraction, got {}",
            self.compact_ratio
        );
        anyhow::ensure!(self.repair_steps >= 1, "repair_steps must be >= 1");
        anyhow::ensure!(
            self.checkpoint_every >= 1,
            "checkpoint_every must be >= 1, got {}",
            self.checkpoint_every
        );
        anyhow::ensure!(
            !self.resume || !self.checkpoint_dir.is_empty(),
            "resume requires a checkpoint directory (--checkpoint dir/)"
        );
        // The coarsest-level algorithm must itself be a registered
        // non-multilevel partitioner (a multilevel coarse_algo would
        // recurse forever). The family list lives next to the registry
        // so a new V-cycle variant cannot dodge this guard.
        let ca = self.coarse_algo.to_lowercase();
        anyhow::ensure!(
            !crate::partitioners::MULTILEVEL_FAMILY.contains(&ca.as_str()),
            "coarse_algo must not be a multilevel algorithm, got {:?}",
            self.coarse_algo
        );
        anyhow::ensure!(
            crate::partitioners::REGISTRY.contains(&ca.as_str()),
            "unknown coarse_algo {:?} (expected one of: {})",
            self.coarse_algo,
            crate::partitioners::REGISTRY.join("|")
        );
        Ok(())
    }

    /// Load from a TOML-subset file; keys may be flat or under
    /// `[revolver]`.
    pub fn from_toml_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read config {:?}", path.as_ref()))?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML-subset text.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let kv = parse_toml_subset(text)?;
        let mut cfg = RevolverConfig::default();
        for (key, value) in &kv {
            // Accept both flat keys and `revolver.` / section-qualified.
            let k = key.strip_prefix("revolver.").unwrap_or(key);
            match k {
                "parts" => cfg.parts = value.parse().context("parts")?,
                "epsilon" => cfg.epsilon = value.parse().context("epsilon")?,
                "max_steps" => cfg.max_steps = value.parse().context("max_steps")?,
                "halt_window" => cfg.halt_window = value.parse().context("halt_window")?,
                "halt_theta" => cfg.halt_theta = value.parse().context("halt_theta")?,
                "alpha" => cfg.alpha = value.parse().context("alpha")?,
                "beta" => cfg.beta = value.parse().context("beta")?,
                "threads" => cfg.threads = value.parse().context("threads")?,
                "schedule" => cfg.schedule = value.parse()?,
                "frontier" => cfg.frontier = value.parse()?,
                "frontier_dense_frac" => {
                    cfg.frontier_dense_frac =
                        value.parse().context("frontier_dense_frac")?
                }
                "prob_format" => cfg.prob_format = value.parse()?,
                "seed" => cfg.seed = value.parse().context("seed")?,
                "execution" => {
                    cfg.execution = match value.as_str() {
                        "async" | "asynchronous" => ExecutionModel::Asynchronous,
                        "sync" | "synchronous" => ExecutionModel::Synchronous,
                        other => bail!("unknown execution model {other:?}"),
                    }
                }
                "engine" => cfg.engine = value.parse()?,
                "artifacts_dir" => cfg.artifacts_dir = value.clone(),
                "classic_la" => cfg.classic_la = value.parse().context("classic_la")?,
                "trace_every" => cfg.trace_every = value.parse().context("trace_every")?,
                "init" => cfg.init = value.parse()?,
                "stream_order" => cfg.stream_order = value.parse()?,
                "fennel_gamma" => cfg.fennel_gamma = value.parse().context("fennel_gamma")?,
                "restream_passes" => {
                    cfg.restream_passes = value.parse().context("restream_passes")?
                }
                "coarsen_until" => cfg.coarsen_until = value.parse().context("coarsen_until")?,
                "refine_steps" => cfg.refine_steps = value.parse().context("refine_steps")?,
                "coarse_algo" => cfg.coarse_algo = value.clone(),
                "compact_ratio" => cfg.compact_ratio = value.parse().context("compact_ratio")?,
                "repair_steps" => cfg.repair_steps = value.parse().context("repair_steps")?,
                "placement" => cfg.placement = value.parse()?,
                "verbosity" => cfg.verbosity = value.parse()?,
                "obs_log" => cfg.obs_log = value.clone(),
                "profile" => cfg.profile = value.parse().context("profile")?,
                "metrics_addr" => cfg.metrics_addr = value.clone(),
                "diag" => cfg.diag = value.parse().context("diag")?,
                "ingest" => cfg.ingest = value.parse()?,
                "checkpoint_dir" => cfg.checkpoint_dir = value.clone(),
                "checkpoint_every" => {
                    cfg.checkpoint_every = value.parse().context("checkpoint_every")?
                }
                "resume" => cfg.resume = value.parse().context("resume")?,
                "faults" => cfg.faults = value.parse()?,
                other => bail!("unknown config key {other:?}"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Parse `key = value` / `[section]` TOML subset into dotted keys.
fn parse_toml_subset(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{}.{}", section, k.trim())
        };
        let mut val = v.trim().to_string();
        // Strip string quotes.
        if (val.starts_with('"') && val.ends_with('"') && val.len() >= 2)
            || (val.starts_with('\'') && val.ends_with('\'') && val.len() >= 2)
        {
            val = val[1..val.len() - 1].to_string();
        }
        out.insert(key, val);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // Respect `#` inside quotes.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_settings() {
        let c = RevolverConfig::default();
        assert_eq!(c.max_steps, 290);
        assert_eq!(c.halt_window, 5);
        assert!((c.halt_theta - 0.001).abs() < 1e-12);
        assert!((c.epsilon - 0.05).abs() < 1e-12);
        assert_eq!(c.alpha, 1.0);
        assert_eq!(c.beta, 0.1);
        c.validate().unwrap();
    }

    #[test]
    fn toml_flat() {
        let c = RevolverConfig::from_toml_str(
            "parts = 16\nepsilon = 0.1\nseed = 7\nengine = \"xla\"\n",
        )
        .unwrap();
        assert_eq!(c.parts, 16);
        assert!((c.epsilon - 0.1).abs() < 1e-12);
        assert_eq!(c.seed, 7);
        assert_eq!(c.engine, Engine::Xla);
    }

    #[test]
    fn toml_sectioned_with_comments() {
        let c = RevolverConfig::from_toml_str(
            "# experiment\n[revolver]\nparts = 4 # four ways\nexecution = \"sync\"\n",
        )
        .unwrap();
        assert_eq!(c.parts, 4);
        assert_eq!(c.execution, ExecutionModel::Synchronous);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(RevolverConfig::from_toml_str("nope = 1\n").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(RevolverConfig::from_toml_str("parts = 1\n").is_err());
        assert!(RevolverConfig::from_toml_str("alpha = 2.0\n").is_err());
        assert!(RevolverConfig::from_toml_str("parts = banana\n").is_err());
    }

    #[test]
    fn verbosity_parse_and_obs_knobs_from_toml() {
        assert_eq!(RevolverConfig::default().verbosity, Verbosity::Info);
        assert_eq!("quiet".parse::<Verbosity>().unwrap(), Verbosity::Quiet);
        assert_eq!("Info".parse::<Verbosity>().unwrap(), Verbosity::Info);
        assert_eq!("DEBUG".parse::<Verbosity>().unwrap(), Verbosity::Debug);
        assert!("loud".parse::<Verbosity>().is_err());
        let c = RevolverConfig::from_toml_str(
            "verbosity = \"quiet\"\nobs_log = \"run.jsonl\"\nprofile = true\n\
             metrics_addr = \"127.0.0.1:0\"\ndiag = true\n",
        )
        .unwrap();
        assert_eq!(c.verbosity, Verbosity::Quiet);
        assert_eq!(c.obs_log, "run.jsonl");
        assert!(c.profile);
        assert_eq!(c.metrics_addr, "127.0.0.1:0");
        assert!(c.diag);
        assert!(!RevolverConfig::default().diag);
        assert!(RevolverConfig::default().metrics_addr.is_empty());
        assert!(RevolverConfig::from_toml_str("profile = maybe\n").is_err());
        assert!(RevolverConfig::from_toml_str("diag = sometimes\n").is_err());
    }

    #[test]
    fn engine_parse() {
        assert_eq!("native".parse::<Engine>().unwrap(), Engine::Native);
        assert_eq!("XLA".parse::<Engine>().unwrap(), Engine::Xla);
        assert!("gpu".parse::<Engine>().is_err());
    }

    #[test]
    fn schedule_parse_and_default() {
        assert_eq!(RevolverConfig::default().schedule, Schedule::Vertex);
        assert_eq!("vertex".parse::<Schedule>().unwrap(), Schedule::Vertex);
        assert_eq!("Degree".parse::<Schedule>().unwrap(), Schedule::Degree);
        assert!("random".parse::<Schedule>().is_err());
    }

    #[test]
    fn schedule_from_toml() {
        let c = RevolverConfig::from_toml_str("schedule = \"degree\"\n").unwrap();
        assert_eq!(c.schedule, Schedule::Degree);
        let c = RevolverConfig::from_toml_str("[revolver]\nschedule = \"vertex\"\n").unwrap();
        assert_eq!(c.schedule, Schedule::Vertex);
    }

    #[test]
    fn frontier_parse_default_and_toml() {
        assert_eq!(RevolverConfig::default().frontier, Frontier::On);
        assert_eq!("on".parse::<Frontier>().unwrap(), Frontier::On);
        assert_eq!("OFF".parse::<Frontier>().unwrap(), Frontier::Off);
        assert_eq!("true".parse::<Frontier>().unwrap(), Frontier::On);
        assert!("maybe".parse::<Frontier>().is_err());
        let c = RevolverConfig::from_toml_str("frontier = \"off\"\n").unwrap();
        assert_eq!(c.frontier, Frontier::Off);
        let c = RevolverConfig::from_toml_str("[revolver]\nfrontier = \"on\"\n").unwrap();
        assert_eq!(c.frontier, Frontier::On);
    }

    #[test]
    fn prob_format_parse_default_and_toml() {
        assert_eq!(RevolverConfig::default().prob_format, ProbFormat::Q16);
        assert_eq!("q16".parse::<ProbFormat>().unwrap(), ProbFormat::Q16);
        assert_eq!("F32".parse::<ProbFormat>().unwrap(), ProbFormat::F32);
        assert_eq!("fixed".parse::<ProbFormat>().unwrap(), ProbFormat::Q16);
        assert!("f64".parse::<ProbFormat>().is_err());
        let c = RevolverConfig::from_toml_str("prob_format = \"f32\"\n").unwrap();
        assert_eq!(c.prob_format, ProbFormat::F32);
        let c = RevolverConfig::from_toml_str("[revolver]\nprob_format = \"q16\"\n").unwrap();
        assert_eq!(c.prob_format, ProbFormat::Q16);
    }

    #[test]
    fn frontier_dense_frac_default_toml_and_validation() {
        let d = RevolverConfig::default();
        assert!((d.frontier_dense_frac - 0.25).abs() < 1e-12);
        let c = RevolverConfig::from_toml_str("frontier_dense_frac = 0.5\n").unwrap();
        assert!((c.frontier_dense_frac - 0.5).abs() < 1e-12);
        // Degenerate endpoints are legal (scan-always / worklist-always).
        assert!(RevolverConfig::from_toml_str("frontier_dense_frac = 0.0\n").is_ok());
        assert!(RevolverConfig::from_toml_str("frontier_dense_frac = 1.0\n").is_ok());
        assert!(RevolverConfig::from_toml_str("frontier_dense_frac = 1.5\n").is_err());
        assert!(RevolverConfig::from_toml_str("frontier_dense_frac = -0.1\n").is_err());
    }

    #[test]
    fn init_parse() {
        assert_eq!("random".parse::<Init>().unwrap(), Init::Random);
        assert_eq!(
            "stream:fennel".parse::<Init>().unwrap(),
            Init::Stream(StreamAlgo::Fennel)
        );
        assert_eq!("STREAM:LDG".parse::<Init>().unwrap(), Init::Stream(StreamAlgo::Ldg));
        assert!("stream:metis".parse::<Init>().is_err());
        assert!("warm".parse::<Init>().is_err());
    }

    #[test]
    fn stream_knobs_from_toml() {
        let c = RevolverConfig::from_toml_str(
            "init = \"stream:restream\"\nstream_order = \"bfs\"\nfennel_gamma = 2.0\nrestream_passes = 5\n",
        )
        .unwrap();
        assert_eq!(c.init, Init::Stream(StreamAlgo::Restream));
        assert_eq!(c.stream_order, StreamOrder::Bfs);
        assert!((c.fennel_gamma - 2.0).abs() < 1e-12);
        assert_eq!(c.restream_passes, 5);
    }

    #[test]
    fn stream_defaults_and_validation() {
        let c = RevolverConfig::default();
        assert_eq!(c.init, Init::Random);
        assert_eq!(c.stream_order, StreamOrder::Natural);
        assert!((c.fennel_gamma - 1.5).abs() < 1e-12);
        assert_eq!(c.restream_passes, 3);
        assert!(RevolverConfig::from_toml_str("fennel_gamma = 1.0\n").is_err());
        assert!(RevolverConfig::from_toml_str("restream_passes = 0\n").is_err());
    }

    #[test]
    fn multilevel_knobs_from_toml_and_validation() {
        let c = RevolverConfig::from_toml_str(
            "coarsen_until = 64\nrefine_steps = 4\ncoarse_algo = \"ldg\"\n",
        )
        .unwrap();
        assert_eq!(c.coarsen_until, 64);
        assert_eq!(c.refine_steps, 4);
        assert_eq!(c.coarse_algo, "ldg");

        let d = RevolverConfig::default();
        assert_eq!(d.coarsen_until, 256);
        assert_eq!(d.refine_steps, 10);
        assert_eq!(d.coarse_algo, "fennel");

        assert!(RevolverConfig::from_toml_str("coarsen_until = 1\n").is_err());
        assert!(RevolverConfig::from_toml_str("refine_steps = 0\n").is_err());
        // Unknown and recursive coarse algorithms are rejected eagerly.
        assert!(RevolverConfig::from_toml_str("coarse_algo = \"metis\"\n").is_err());
        assert!(RevolverConfig::from_toml_str("coarse_algo = \"multilevel\"\n").is_err());
        assert!(RevolverConfig::from_toml_str("coarse_algo = \"ml-revolver\"\n").is_err());
    }

    #[test]
    fn dynamic_knobs_from_toml_and_validation() {
        let c = RevolverConfig::from_toml_str(
            "compact_ratio = 0.5\nrepair_steps = 4\nplacement = \"ldg\"\n",
        )
        .unwrap();
        assert!((c.compact_ratio - 0.5).abs() < 1e-12);
        assert_eq!(c.repair_steps, 4);
        assert_eq!(c.placement, Placement::Ldg);

        let d = RevolverConfig::default();
        assert!((d.compact_ratio - 0.25).abs() < 1e-12);
        assert_eq!(d.repair_steps, 10);
        assert_eq!(d.placement, Placement::Fennel);

        assert!(RevolverConfig::from_toml_str("compact_ratio = 0\n").is_err());
        assert!(RevolverConfig::from_toml_str("compact_ratio = -1.0\n").is_err());
        assert!(RevolverConfig::from_toml_str("repair_steps = 0\n").is_err());
        assert!(RevolverConfig::from_toml_str("placement = \"restream\"\n").is_err());
    }

    #[test]
    fn placement_parse() {
        assert_eq!("ldg".parse::<Placement>().unwrap(), Placement::Ldg);
        assert_eq!("FENNEL".parse::<Placement>().unwrap(), Placement::Fennel);
        assert!("hash".parse::<Placement>().is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let c =
            RevolverConfig::from_toml_str("artifacts_dir = \"my#dir\"\n").unwrap();
        assert_eq!(c.artifacts_dir, "my#dir");
    }

    #[test]
    fn ingest_mode_parse() {
        assert_eq!("strict".parse::<IngestMode>().unwrap(), IngestMode::Strict);
        assert_eq!("LENIENT".parse::<IngestMode>().unwrap(), IngestMode::Lenient);
        assert!("yolo".parse::<IngestMode>().is_err());
        assert_eq!(IngestMode::default(), IngestMode::Strict);
    }

    #[test]
    fn fault_tolerance_keys_parse_and_validate() {
        let c = RevolverConfig::from_toml_str(
            "ingest = \"lenient\"\n\
             checkpoint_dir = \"ckpt\"\n\
             checkpoint_every = 3\n\
             resume = true\n\
             faults = \"panic@step:7,io@checkpoint:2\"\n",
        )
        .unwrap();
        assert_eq!(c.ingest, IngestMode::Lenient);
        assert_eq!(c.checkpoint_dir, "ckpt");
        assert_eq!(c.checkpoint_every, 3);
        assert!(c.resume);
        assert_eq!(c.faults.panic_at_step, Some(7));
        assert_eq!(c.faults.io_at_checkpoint, Some(2));

        let d = RevolverConfig::default();
        assert_eq!(d.ingest, IngestMode::Strict);
        assert!(d.checkpoint_dir.is_empty());
        assert_eq!(d.checkpoint_every, 10);
        assert!(!d.resume);
        assert!(d.faults.is_empty());

        assert!(RevolverConfig::from_toml_str("checkpoint_every = 0\n").is_err());
        // resume without a checkpoint dir is a config error.
        assert!(RevolverConfig::from_toml_str("resume = true\n").is_err());
        assert!(RevolverConfig::from_toml_str("faults = \"explode@heap:1\"\n").is_err());
        assert!(RevolverConfig::from_toml_str("ingest = \"sloppy\"\n").is_err());
    }
}
