//! Range partitioning (§V-D): vertex `v` goes to partition
//! `⌊v·k/|V|⌋` — contiguous id ranges.
//!
//! Wins on graphs whose ids carry locality (roads, crawl-ordered webs)
//! and loses catastrophically on load balance when degree mass is
//! concentrated in an id range (§V-H.1: up to 60× worse max load).

use super::{PartitionOutput, Partitioner};
use crate::graph::Graph;
use crate::metrics::trace::RunTrace;

pub struct RangePartitioner {
    k: usize,
}

impl RangePartitioner {
    pub fn new(k: usize) -> Self {
        assert!(k >= 2);
        RangePartitioner { k }
    }
}

impl Partitioner for RangePartitioner {
    fn name(&self) -> &'static str {
        "range"
    }

    fn try_partition(&self, g: &Graph) -> Result<PartitionOutput, crate::engine::EngineError> {
        let n = g.num_vertices() as u128;
        let k = self.k as u128;
        let labels = (0..g.num_vertices())
            .map(|v| ((v as u128 * k) / n) as u32)
            .collect();
        Ok(PartitionOutput { labels, trace: RunTrace::default() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_dataset, Dataset};
    use crate::metrics::quality;

    #[test]
    fn contiguous_ranges() {
        let g = generate_dataset(Dataset::So, 1000, 1).unwrap();
        let out = RangePartitioner::new(4).partition(&g);
        // Labels must be non-decreasing in v, span exactly 0..k.
        for w in out.labels.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert_eq!(*out.labels.first().unwrap(), 0);
        assert_eq!(*out.labels.last().unwrap(), 3);
    }

    #[test]
    fn wins_local_edges_on_road() {
        // §V-G.4: Range must beat Hash decisively on road networks.
        let g = generate_dataset(Dataset::Usa, 4096, 2).unwrap();
        let k = 8;
        let range_le = quality::local_edges(
            &g,
            &RangePartitioner::new(k).partition(&g).labels,
        );
        let hash_le = quality::local_edges(
            &g,
            &super::super::hash::HashPartitioner::new(k).partition(&g).labels,
        );
        assert!(
            range_le > 3.0 * hash_le,
            "range={range_le} hash={hash_le}"
        );
    }

    #[test]
    fn terrible_load_on_clustered_web() {
        // §V-H.1: on a hub-clustered (UK-like) graph, Range's max load
        // explodes because low-id hubs concentrate degree mass.
        let g = generate_dataset(Dataset::Uk, 4096, 3).unwrap();
        let k = 16;
        let mnl = quality::max_normalized_load(
            &g,
            &RangePartitioner::new(k).partition(&g).labels,
            k,
        );
        assert!(mnl > 2.0, "expected badly imbalanced, got {mnl}");
    }
}
