//! Revolver — the paper's contribution (§IV): asynchronous vertex-centric
//! partitioning where each vertex's **weighted learning automaton** picks
//! its partition and is trained by the **normalized LP** objective.
//!
//! Step structure (§IV-D, Figure 2):
//!  1. every LA draws an action (candidate partition) — roulette wheel;
//!  2. candidates register migration *demand* m(l);
//!  3. normalized LP scores (eqs. 10–12) are computed per vertex and the
//!     argmax label λ(v) is published for neighbours;
//!  4. the vertex migrates to its selected action with probability
//!     min(1, r(l)/m(l)) when the action differs from its current label;
//!  5. raw weights are accumulated from neighbour λ's (eq. 13);
//!  6. the weight vector is mean-split into reward/penalty halves and
//!     half-normalized (§IV-D.6);
//!  7. the LA probability vector is updated (eqs. 8–9);
//!  8. convergence: halt after `halt_window` consecutive sub-θ steps.
//!
//! **Asynchronous** mode (the paper's headline implementation) reads
//! labels, loads and λ's live from shared atomics — workers see each
//! other's migrations mid-step ("progressively exchanged loads",
//! §V-H.2). **Synchronous** mode (ablation E4) freezes label/λ/load
//! snapshots per step, Giraph-style.
//!
//! Execution is delegated to [`crate::engine`]: steps 1–2 are the
//! engine's phase A, steps 3–7 its phase B, and λ(v) rides the engine's
//! per-vertex *published* channel (so the sync-mode freeze applies to it
//! automatically). This module only contains the per-vertex math; all
//! thread orchestration, snapshotting and halting live in the engine.
//!
//! Eq. (13) note: the printed equation mixes λ(v)/λ(u) and ψ indices
//! inconsistently; we implement the reading consistent with §IV-C step 4
//! ("scores … are evaluated by (13) to form the weight vector W"): the
//! raw weight vector starts from the vertex's own score vector, and each
//! neighbour u endorses partition λ(u) with ŵ(u,v)/Σŵ when v's selected
//! action agrees, else 1/Σŵ while λ(u) has migration headroom. DESIGN.md
//! §Fidelity-notes (F5–F7) records this and the other disambiguations.

use std::cell::UnsafeCell;

use super::{PartitionOutput, Partitioner};
use crate::config::{Engine, ExecutionModel, ProbFormat, RevolverConfig};
use crate::engine::{self, StepCtx, StepStats, VertexProgram};
use crate::graph::Graph;
use crate::la::signal::build_signals_overlay_into;
use crate::la::weighted::WeightedLa;
use crate::la::{roulette, Signal};
use crate::lp::{
    argmax, clear_touched, clear_touched_u32, neighbor_histogram,
    neighbor_histogram_counts_sparse, neighbor_histogram_sparse, normalized as nlp,
};
use crate::partition::{DemandTracker, InitialAssignment, PartitionState};
use crate::runtime::XlaStepEngine;
use crate::util::rng::Rng;
use crate::VertexId;

/// How many vertices share one load/π snapshot in the scoring loop (and
/// one XLA batch in `--engine xla`; must match the artifact batch dim).
pub const BATCH: usize = 256;

pub struct Revolver {
    cfg: RevolverConfig,
}

impl Revolver {
    pub fn new(cfg: RevolverConfig) -> Self {
        cfg.validate().expect("invalid config");
        Revolver { cfg }
    }

    /// Access the effective configuration.
    pub fn config(&self) -> &RevolverConfig {
        &self.cfg
    }
}

/// One probability unit in q16 fixed point: q = round(p·65535), so the
/// whole [0, 1] range of an LA probability maps onto the full u16 span.
const Q16_ONE: f32 = 65535.0;

/// The LA probability rows (n × k), shared across all workers.
/// Rows are handed out mutably through `&self`; soundness rests on the
/// engine's scheduling contract ([`VertexProgram`] docs): a vertex
/// appears in exactly one worker's work list per superstep (chunk
/// cover-exactly + frontier dedup), so no two threads ever touch the
/// same row concurrently. The slab replaces the old per-chunk slabs —
/// under frontier-driven scheduling a worker's per-step work list is
/// not aligned with any static vertex range, so per-vertex persistent
/// state must be globally addressable.
///
/// Storage is format-selected ([`ProbFormat`]): `F32` keeps the exact
/// rows the LA math produces (the bit-parity reference), `Q16` stores
/// each probability as u16 fixed point — half the slab bytes, integer
/// roulette wheels ([`roulette::spin_u16`]), and a dequantize →
/// update → requantize round-trip per LA update (the update arithmetic
/// itself stays the f32 [`WeightedLa::update`], so the only difference
/// from the F32 path is the ±½ulp₁₆ storage rounding).
pub struct ProbSlab {
    k: usize,
    data: SlabData,
}

enum SlabData {
    F32(Vec<UnsafeCell<f32>>),
    Q16(Vec<UnsafeCell<u16>>),
}

// SAFETY: concurrent access is only ever to disjoint rows (see above);
// `UnsafeCell` makes the aliasing explicit instead of lying with `&mut`.
unsafe impl Sync for ProbSlab {}

impl ProbSlab {
    pub fn new(
        n: usize,
        k: usize,
        warm: Option<&[crate::Label]>,
        format: ProbFormat,
    ) -> Self {
        let mut flat = vec![0.0f32; n * k];
        match warm {
            None => {
                for row in flat.chunks_mut(k) {
                    WeightedLa::init(row);
                }
            }
            Some(labels) => {
                for (v, row) in flat.chunks_mut(k).enumerate() {
                    init_warm_row(row, labels[v] as usize);
                }
            }
        }
        let data = match format {
            ProbFormat::F32 => {
                SlabData::F32(flat.into_iter().map(UnsafeCell::new).collect())
            }
            ProbFormat::Q16 => SlabData::Q16(
                flat.into_iter().map(|p| UnsafeCell::new(Self::quantize(p))).collect(),
            ),
        };
        ProbSlab { k, data }
    }

    /// Actions per row.
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    fn quantize(p: f32) -> u16 {
        // `as` saturates, so a renormalized row (p ≤ 1 up to float
        // drift) can never wrap.
        (p * Q16_ONE).round() as u16
    }

    /// Vertex `v`'s raw f32 row; F32 storage only.
    ///
    /// SAFETY: the caller must be the only thread evaluating `v` in the
    /// current phase — guaranteed by the engine's disjoint work lists.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    unsafe fn f32_row(&self, v: usize) -> &mut [f32] {
        match &self.data {
            SlabData::F32(cells) => std::slice::from_raw_parts_mut(
                cells.as_ptr().add(v * self.k) as *mut f32,
                self.k,
            ),
            SlabData::Q16(_) => unreachable!("f32_row on a Q16 slab"),
        }
    }

    /// Vertex `v`'s raw q16 row; Q16 storage only. SAFETY: as
    /// [`Self::f32_row`].
    #[allow(clippy::mut_from_ref)]
    #[inline]
    unsafe fn q16_row(&self, v: usize) -> &mut [u16] {
        match &self.data {
            SlabData::Q16(cells) => std::slice::from_raw_parts_mut(
                cells.as_ptr().add(v * self.k) as *mut u16,
                self.k,
            ),
            SlabData::F32(_) => unreachable!("q16_row on an F32 slab"),
        }
    }

    /// Roulette draw from `v`'s row — native wheel per format (the q16
    /// wheel spins on integer weights, no dequantization).
    ///
    /// SAFETY: as [`Self::f32_row`].
    #[inline]
    unsafe fn spin(&self, v: usize, rng: &mut Rng) -> usize {
        match &self.data {
            SlabData::F32(_) => roulette::spin(self.f32_row(v), rng),
            SlabData::Q16(_) => roulette::spin_u16(self.q16_row(v), rng),
        }
    }

    /// Copy `v`'s row into `out` as f32 (dequantizing under Q16).
    ///
    /// SAFETY: as [`Self::f32_row`].
    #[inline]
    unsafe fn read_row(&self, v: usize, out: &mut [f32]) {
        match &self.data {
            SlabData::F32(_) => out.copy_from_slice(self.f32_row(v)),
            SlabData::Q16(_) => {
                for (o, &q) in out.iter_mut().zip(self.q16_row(v).iter()) {
                    *o = q as f32 * (1.0 / Q16_ONE);
                }
            }
        }
    }

    /// Store an f32 row back into `v`'s slot (quantizing under Q16).
    ///
    /// SAFETY: as [`Self::f32_row`].
    #[inline]
    unsafe fn write_row(&self, v: usize, row: &[f32]) {
        match &self.data {
            SlabData::F32(_) => self.f32_row(v).copy_from_slice(row),
            SlabData::Q16(_) => {
                for (q, &p) in self.q16_row(v).iter_mut().zip(row.iter()) {
                    *q = Self::quantize(p);
                }
            }
        }
    }

    /// Apply `update` to `v`'s row in f32 space: in place for F32
    /// storage, through the `scratch` round-trip for Q16.
    ///
    /// SAFETY: as [`Self::f32_row`].
    #[inline]
    unsafe fn with_row_mut(
        &self,
        v: usize,
        scratch: &mut [f32],
        update: impl FnOnce(&mut [f32]),
    ) {
        match &self.data {
            SlabData::F32(_) => update(self.f32_row(v)),
            SlabData::Q16(_) => {
                self.read_row(v, scratch);
                update(scratch);
                self.write_row(v, scratch);
            }
        }
    }

    // ── Safe single-threaded wrappers (benches/tests): `&mut self`
    // guarantees the exclusivity the unsafe accessors require. ──

    /// [`Self::spin`] for exclusive owners.
    pub fn spin_mut(&mut self, v: usize, rng: &mut Rng) -> usize {
        unsafe { self.spin(v, rng) }
    }

    /// One weighted-LA update of `v`'s row (dequantize → update →
    /// requantize under Q16); `scratch` must be k-sized.
    pub fn update_row_mut(
        &mut self,
        v: usize,
        scratch: &mut [f32],
        weights: &[f32],
        signals: &[Signal],
        alpha: f32,
        beta: f32,
    ) {
        unsafe {
            self.with_row_mut(v, scratch, |row| {
                WeightedLa::update(row, weights, signals, alpha, beta)
            })
        }
    }

    /// Copy of `v`'s row as f32 (dequantized under Q16) — test/bench
    /// inspection.
    pub fn row_vec(&mut self, v: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.k];
        unsafe { self.read_row(v, &mut out) };
        out
    }

    /// Snapshot the whole slab into a checkpointable [`crate::fault::LaSlab`],
    /// preserving the storage format exactly (no quantization round-trip,
    /// so a resumed Q16 run restarts from bit-identical rows).
    ///
    /// Called from [`VertexProgram::la_checkpoint`] between supersteps:
    /// the coordinator snapshots while every worker is parked at the W1
    /// barrier, and rows are only mutated inside phases, so the
    /// `UnsafeCell` reads observe a quiescent slab.
    pub fn dump(&self) -> crate::fault::LaSlab {
        match &self.data {
            SlabData::F32(cells) => crate::fault::LaSlab::F32 {
                cols: self.k as u32,
                data: cells.iter().map(|c| unsafe { *c.get() }).collect(),
            },
            SlabData::Q16(cells) => crate::fault::LaSlab::Q16 {
                cols: self.k as u32,
                data: cells.iter().map(|c| unsafe { *c.get() }).collect(),
            },
        }
    }

    /// Rebuild a slab from a checkpointed [`crate::fault::LaSlab`].
    /// Returns `None` on a shape mismatch (wrong n or k — e.g. resuming
    /// against a different graph), letting the caller fall back to a
    /// warm start instead of resuming from nonsense rows.
    pub fn from_checkpoint(n: usize, k: usize, la: &crate::fault::LaSlab) -> Option<Self> {
        if la.rows() != n || la.cols() as usize != k {
            return None;
        }
        let data = match la {
            crate::fault::LaSlab::F32 { data, .. } => {
                SlabData::F32(data.iter().map(|&p| UnsafeCell::new(p)).collect())
            }
            crate::fault::LaSlab::Q16 { data, .. } => {
                SlabData::Q16(data.iter().map(|&q| UnsafeCell::new(q)).collect())
            }
        };
        Some(ProbSlab { k, data })
    }
}

/// Per-worker mutable scratch: the k-sized scoring buffers plus the
/// positional phase-A → phase-B hand-off, so the hot loop never
/// allocates.
struct ChunkState {
    /// The action each LA of this worker's *current work list* selected
    /// this step — positional (index `i` ↔ `work[i]`), relying on the
    /// engine's guarantee that both phases see the identical list.
    selected: Vec<u32>,
    k: usize,
    // Scratch (k-sized).
    /// All-zero between vertices; the sparse accumulation records which
    /// entries it dirtied in `touched` and clears only those (O(deg)
    /// instead of an O(k) fill per vertex — wins when k ≫ avg degree).
    hist: Vec<f32>,
    /// u32 twin of `hist` for the integer-weight fast path
    /// ([`neighbor_histogram_counts_sparse`]); same all-zero contract.
    hist_u32: Vec<u32>,
    touched: Vec<u32>,
    scores: Vec<f32>,
    pi: Vec<f32>,
    /// Sparse eq.-(13) neighbour-modulation overlay: all-zero between
    /// vertices, dirtied entries tracked in `touched_w`, consumed via
    /// [`build_signals_overlay_into`] against the dense `scores` base —
    /// O(deg) writes instead of the old O(k) `raw_w` copy per vertex.
    overlay: Vec<f32>,
    touched_w: Vec<u32>,
    w_norm: Vec<f32>,
    signals: Vec<Signal>,
    loads: Vec<f32>,
    /// f32 staging row for Q16 slab round-trips (unused under F32).
    prob_row: Vec<f32>,
    /// Per-batch precomputed "partition still has migration headroom"
    /// flags — replaces two atomic loads per neighbour in the eq.-(13)
    /// accumulation (perf log P3).
    headroom: Vec<bool>,
}

/// Warm-start mass on the streamed label: the row starts at
/// `1/k + WARM_BIAS·(1 − 1/k)` there — i.e. halfway between uniform
/// and deterministic — and the remainder spreads evenly, so the LA
/// keeps exploring but no longer burns steps rediscovering the
/// streaming pass's structure.
const WARM_BIAS: f32 = 0.5;

/// Initialize one LA probability row biased toward `warm`.
/// `hot = 0.5·(k+1)/k`, `cold = 0.5/k`; `hot + (k−1)·cold = 1`.
fn init_warm_row(row: &mut [f32], warm: usize) {
    let k = row.len() as f32;
    let hot = 1.0 / k + WARM_BIAS * (1.0 - 1.0 / k);
    let cold = (1.0 - hot) / (k - 1.0);
    row.fill(cold);
    row[warm] = hot;
}

impl ChunkState {
    fn new(k: usize) -> Self {
        ChunkState {
            selected: Vec::new(),
            k,
            hist: vec![0.0; k],
            hist_u32: vec![0; k],
            touched: Vec::with_capacity(k),
            scores: vec![0.0; k],
            pi: vec![0.0; k],
            overlay: vec![0.0; k],
            touched_w: Vec::with_capacity(k),
            w_norm: vec![0.0; k],
            signals: vec![Signal::Penalty; k],
            loads: vec![0.0; k],
            prob_row: vec![0.0; k],
            headroom: vec![true; k],
        }
    }
}

/// Revolver as a [`VertexProgram`]: phase A draws actions and registers
/// demand, phase B scores/migrates/learns (natively or through the XLA
/// artifacts). The persistent per-vertex LA state lives in the program
/// itself ([`ProbSlab`]); scratch holds only ephemeral buffers.
struct RevolverProgram<'a> {
    cfg: &'a RevolverConfig,
    /// n × k LA probability rows — built uniform, or biased toward the
    /// warm-start labels (`--init stream:<algo>` / multilevel `refine`).
    probs: ProbSlab,
}

impl VertexProgram for RevolverProgram<'_> {
    type Scratch = (ChunkState, Option<XlaStepEngine>);
    type PhaseA = ();
    type PhaseB = ();

    fn execution(&self) -> ExecutionModel {
        self.cfg.execution
    }

    fn rng_salt(&self) -> u64 {
        0x5245564F // "REVO"
    }

    fn init_published(&self, v: VertexId, state: &PartitionState) -> u32 {
        // λ(v) starts at the initial label.
        state.label(v)
    }

    fn make_scratch(&self) -> Self::Scratch {
        // PJRT handles are !Send: construct inside the worker.
        let eng = match self.cfg.engine {
            Engine::Xla => Some(
                XlaStepEngine::load(
                    &self.cfg.artifacts_dir,
                    BATCH,
                    self.cfg.parts,
                    self.cfg.alpha,
                    self.cfg.beta,
                )
                .expect("failed to load XLA artifacts (run `make artifacts`)"),
            ),
            Engine::Native => None,
        };
        (ChunkState::new(self.cfg.parts), eng)
    }

    fn la_checkpoint(&self) -> Option<crate::fault::LaSlab> {
        // Coordinator-side, workers parked at the W1 barrier — the slab
        // is quiescent (see [`ProbSlab::dump`]).
        Some(self.probs.dump())
    }

    fn la_decisiveness(&self, verts: &[VertexId]) -> Option<crate::obs::diag::Decisiveness> {
        // Coordinator-side, same quiescence window as `la_checkpoint`.
        // Frontier-only: the cost is O(|verts|·k), proportional to the
        // step's own phase work, not O(n·k).
        let k = self.cfg.parts;
        let mut row = vec![0.0f32; k];
        let mut d = crate::obs::diag::Decisiveness::default();
        for &v in verts {
            unsafe { self.probs.read_row(v as usize, &mut row) };
            let maxp = row.iter().copied().fold(0.0f32, f32::max) as f64;
            let mut ent = 0.0f64;
            for &p in &row {
                if p > 0.0 {
                    let p = p as f64;
                    ent -= p * p.ln();
                }
            }
            crate::obs::observe("la_row_maxp_milli", (maxp * 1e3) as u64);
            crate::obs::observe("la_row_entropy_millinats", (ent * 1e3) as u64);
            d.rows += 1;
            d.maxp_sum += maxp;
            d.entropy_sum += ent;
        }
        Some(d)
    }

    fn prepare_phase_a(&self, _g: &Graph, _state: &PartitionState, _step: u32) {}

    fn prepare_phase_b(
        &self,
        _g: &Graph,
        _state: &PartitionState,
        _demand: &DemandTracker,
        _step: u32,
    ) {
    }

    fn phase_a(
        &self,
        ctx: &StepCtx<'_>,
        _frozen: &(),
        scratch: &mut Self::Scratch,
        work: &[VertexId],
        rng: &mut Rng,
    ) -> StepStats {
        let cs = &mut scratch.0;
        crate::obs::counter_add("revolver_spins", work.len() as u64);
        // ── Action selection + demand (§IV-D.1/2) ──
        cs.selected.clear();
        for &v in work {
            // Frontier fast path, mirroring phase B's: an isolated
            // vertex is inert under active-set execution, so don't draw
            // an action or register demand it will never consume (dead
            // demand would deflate min(1, r(l)/m(l)) for real movers).
            // The positional slot still needs an entry; the current
            // label is the harmless "stay" action.
            if ctx.frontier_on() && ctx.graph.neighbors(v).is_empty() {
                cs.selected.push(ctx.state.label(v));
                continue;
            }
            // SAFETY: `v` is in this worker's work list only (engine
            // contract), so the row access is exclusive.
            let a = unsafe { self.probs.spin(v as usize, rng) } as u32;
            cs.selected.push(a);
            if a != ctx.state.label(v) {
                ctx.demand.add(a as usize, ctx.graph.load_mass(v));
            }
        }
        StepStats::default()
    }

    fn phase_b(
        &self,
        ctx: &StepCtx<'_>,
        _frozen: &(),
        scratch: &mut Self::Scratch,
        work: &[VertexId],
        rng: &mut Rng,
    ) -> StepStats {
        let (cs, eng) = scratch;
        crate::obs::counter_add("revolver_la_updates", work.len() as u64);
        let k = cs.k;
        let mut stats = StepStats::default();
        let mut pos = 0usize; // position into `work` / `cs.selected`
        for batch in work.chunks(BATCH) {
            // One load/π snapshot per batch (async staleness tolerance;
            // exactly the artifact's granularity).
            ctx.state.loads_into(&mut cs.loads);
            nlp::penalty_into(&cs.loads, ctx.state.system_capacity() as f32, &mut cs.pi);
            let cap = ctx.state.capacity() as f32;
            for l in 0..k {
                cs.headroom[l] = ctx.demand.get(l) <= 0 || cs.loads[l] < cap;
            }
            match eng.as_mut() {
                Some(eng) => {
                    stats.score_sum += xla_batch(
                        ctx,
                        cs,
                        &self.probs,
                        eng,
                        batch,
                        pos,
                        rng,
                        &mut stats.migrations,
                    );
                }
                None => {
                    for (i, &v) in batch.iter().enumerate() {
                        let action = cs.selected[pos + i];
                        stats.score_sum += native_vertex(
                            ctx,
                            cs,
                            &self.probs,
                            v,
                            action,
                            rng,
                            &mut stats.migrations,
                            self.cfg,
                        );
                    }
                }
            }
            pos += batch.len();
        }
        stats
    }
}

impl Partitioner for Revolver {
    fn name(&self) -> &'static str {
        "revolver"
    }

    fn try_partition(&self, g: &Graph) -> Result<PartitionOutput, engine::EngineError> {
        // Probe the XLA engine on the main thread first: a worker panic
        // behind the barrier protocol used to deadlock the coordinator;
        // containment now turns it into an `Err`, but configuration
        // errors (missing artifacts, wrong k, mismatched alpha/beta)
        // still surface more usefully eagerly and cleanly here.
        if self.cfg.engine == Engine::Xla {
            XlaStepEngine::load(
                &self.cfg.artifacts_dir,
                BATCH,
                self.cfg.parts,
                self.cfg.alpha,
                self.cfg.beta,
            )
            .expect("failed to load XLA artifacts (run `make artifacts`)");
        }
        // Compute the initial assignment once: the engine seeds the
        // shared labels from it, and (for a streaming warm start) the
        // program biases each LA row toward its vertex's label.
        let init = engine::initial_assignment(g, &self.cfg);
        let warm = match &init {
            InitialAssignment::Given(labels) => Some(labels.clone()),
            _ => None,
        };
        let program = RevolverProgram {
            cfg: &self.cfg,
            probs: ProbSlab::new(
                g.num_vertices(),
                self.cfg.parts,
                warm.as_deref(),
                self.cfg.prob_format,
            ),
        };
        engine::run_with_init(g, &self.cfg, &program, init)
    }
}

/// Run a bounded Revolver pass from an explicit initial assignment —
/// the multilevel V-cycle's per-level refiner. Every LA row starts
/// biased toward its vertex's given label (the same warm start the
/// streaming bridge uses), and on graphs with vertex weights the
/// demand/migration mass is the coarse vertex weight
/// ([`Graph::load_mass`]).
pub fn refine(
    g: &Graph,
    cfg: &RevolverConfig,
    init: Vec<crate::Label>,
) -> Result<PartitionOutput, engine::EngineError> {
    let program = RevolverProgram {
        cfg,
        probs: ProbSlab::new(g.num_vertices(), cfg.parts, Some(&init), cfg.prob_format),
    };
    engine::run_with_init(g, cfg, &program, InitialAssignment::Given(init))
}

/// Resume a Revolver run from a checkpointed assignment and (when the
/// snapshot carried one with matching shape) the exact LA probability
/// slab — the `--resume` continuation path. A missing or shape-mismatched
/// slab degrades to the standard warm start biased toward the
/// checkpointed labels: strictly worse than the exact rows, strictly
/// better than restarting cold.
pub fn resume(
    g: &Graph,
    cfg: &RevolverConfig,
    init: Vec<crate::Label>,
    la: Option<&crate::fault::LaSlab>,
) -> Result<PartitionOutput, engine::EngineError> {
    let probs = la
        .and_then(|slab| ProbSlab::from_checkpoint(g.num_vertices(), cfg.parts, slab))
        .unwrap_or_else(|| {
            ProbSlab::new(g.num_vertices(), cfg.parts, Some(&init), cfg.prob_format)
        });
    let program = RevolverProgram { cfg, probs };
    engine::run_with_init(g, cfg, &program, InitialAssignment::Given(init))
}

/// [`refine`] with an explicit step-0 frontier: only `seeds` (plus
/// whatever their evaluation wakes) are re-evaluated, and every LA row
/// still starts biased toward its given label — the incremental repair
/// pass of [`crate::dynamic`].
pub fn refine_seeded(
    g: &Graph,
    cfg: &RevolverConfig,
    init: Vec<crate::Label>,
    seeds: Vec<crate::VertexId>,
) -> Result<PartitionOutput, engine::EngineError> {
    let program = RevolverProgram {
        cfg,
        probs: ProbSlab::new(g.num_vertices(), cfg.parts, Some(&init), cfg.prob_format),
    };
    engine::run_with_frontier(
        g,
        cfg,
        &program,
        InitialAssignment::Given(init),
        engine::InitialFrontier::Seeds(seeds),
    )
}

/// Native per-vertex phase-B body. Returns the vertex's score
/// contribution to the convergence signal S.
#[inline]
#[allow(clippy::too_many_arguments)]
fn native_vertex(
    ctx: &StepCtx<'_>,
    cs: &mut ChunkState,
    probs: &ProbSlab,
    vid: VertexId,
    action: u32,
    rng: &mut Rng,
    migrations: &mut u64,
    cfg: &RevolverConfig,
) -> f64 {
    let g = ctx.graph;
    let state = ctx.state;

    // Frontier fast path: an isolated vertex has no neighbourhood term,
    // so its score is pure penalty — evaluating it would chase the
    // globally emptiest partition forever (label churn with zero load
    // mass and nobody to wake). Under active-set execution it is
    // settled by construction: no migration, no λ change, no wakes —
    // it leaves the frontier after step 0. Legacy mode (`frontier=off`)
    // keeps the paper-faithful evaluation bit-exactly.
    if ctx.frontier_on() && g.neighbors(vid).is_empty() {
        return 0.0;
    }

    // 3. Normalized LP scores + λ(v) (eqs. 10-12). The histogram is
    // accumulated sparsely: the scratch is all-zero between vertices and
    // only the entries this vertex touched are cleared afterwards. On
    // graphs with eq.-(4) integer weights (the paper's datasets) the
    // gather runs over the contiguous u32 layout — half the histogram
    // bytes, no FP adds — and is bit-exact to the f32 path (lp tests).
    let (best, wsum) = if !g.is_weighted() {
        let cnt = neighbor_histogram_counts_sparse(
            g.neighbors(vid),
            g.neighbor_weights(vid),
            |u| ctx.label(u),
            &mut cs.hist_u32,
            &mut cs.touched,
        );
        let best = nlp::score_counts_into(&cs.hist_u32, cnt, &cs.pi, &mut cs.scores);
        clear_touched_u32(&mut cs.hist_u32, &mut cs.touched);
        (best, cnt as f32)
    } else {
        let wsum = neighbor_histogram_sparse(
            g.neighbors(vid),
            g.neighbor_weights(vid),
            |u| ctx.label(u),
            &mut cs.hist,
            &mut cs.touched,
        );
        let best = nlp::score_into(&cs.hist, wsum, &cs.pi, &mut cs.scores);
        clear_touched(&mut cs.hist, &mut cs.touched);
        (best, wsum)
    };
    ctx.publish(vid, best as u32);

    // 4. Migration (§IV-D.4): move to the sampled action when it beats
    // the current partition's score (the Spinner-candidate analogue —
    // Spinner also never migrates to a lower-score partition) and the
    // capacity gate admits it. Vertices sitting in an *over-capacity*
    // partition may leave unconditionally — draining b(l) > C back
    // under the eq. (1) bound takes precedence over locality.
    let current = state.label(vid);
    if action != current
        && (cs.scores[action as usize] >= cs.scores[current as usize]
            || state.remaining(current as usize) < 0.0)
    {
        let p = ctx.demand.migration_probability(state, action as usize);
        if p > 0.0 && rng.next_f64() < p {
            ctx.migrate(vid, action, g.load_mass(vid));
            *migrations += 1;
        }
    }
    // Convergence signal S: the score of the vertex's (post-migration)
    // assignment — the same global objective Spinner's halting check
    // uses; the *best* score is a noisy constant on small graphs while
    // this tracks actual assignment quality.
    let current_score = cs.scores[state.label(vid) as usize] as f64;

    // 6+7. Signals + LA update (§IV-D.6/7).
    // SAFETY (both arms): exclusive row access per the engine's
    // disjoint work lists.
    if cfg.classic_la {
        // Ablation E5: classic single-action update (eqs. 6-7) — reward
        // the selected action iff it matches λ(v). (Eq. 13's weight
        // vector only feeds the weighted update, so it is skipped here.)
        let sig = if action as usize == best { Signal::Reward } else { Signal::Penalty };
        unsafe {
            probs.with_row_mut(vid as usize, &mut cs.prob_row, |row| {
                classic_update_row(row, action as usize, sig, cfg.alpha, cfg.beta)
            });
        }
    } else {
        // 5. Raw weights (§IV-C step 4 + eq. 13): the normalized LP
        // scores ("scores generated from multiple passes of (10) are
        // evaluated by (13) to form the weight vector W") plus the
        // τ-normalized neighbour-preference modulation — neighbour u
        // endorses partition λ(u) with ŵ(u,v)/Σŵ when v's action
        // agrees, else with 1/Σŵ while λ(u) still has migration
        // headroom. The modulation lands in the sparse `overlay`
        // (all-zero between vertices, O(deg) entries dirtied) and the
        // signal builder reads `scores[l] + overlay[l]` on the fly —
        // the old dense `raw_w` seed copy never materializes.
        let wsum_inv = if wsum > 1e-12 { 1.0 / wsum } else { 0.0 };
        if wsum_inv > 0.0 {
            for (&u, &w_uv) in g.neighbors(vid).iter().zip(g.neighbor_weights(vid)) {
                let lu = ctx.published(u) as usize;
                let add = if lu == action as usize {
                    w_uv * wsum_inv
                } else if cs.headroom[lu] {
                    wsum_inv
                } else {
                    continue;
                };
                // Adds are strictly positive (ŵ > 0), so an entry is
                // zero exactly until its first touch.
                if cs.overlay[lu] == 0.0 {
                    cs.touched_w.push(lu as u32);
                }
                cs.overlay[lu] += add;
            }
        }
        build_signals_overlay_into(&cs.scores, &cs.overlay, &mut cs.w_norm, &mut cs.signals);
        clear_touched(&mut cs.overlay, &mut cs.touched_w);
        let ChunkState { prob_row, w_norm, signals, .. } = cs;
        unsafe {
            probs.with_row_mut(vid as usize, prob_row, |row| {
                WeightedLa::update(row, w_norm, signals, cfg.alpha, cfg.beta)
            });
        }
    }

    // Keep the vertex in the frontier while it is unsettled: off its
    // argmax (a denied or unattempted improving move must retry — the
    // demand gate and loads it lost to are global state), or sitting in
    // an over-capacity partition (the unconditional eq.-(1) drain above
    // must keep retrying until b(l) ≤ C, even when label == argmax).
    let post = state.label(vid);
    if post != best as u32 || state.remaining(post as usize) < 0.0 {
        ctx.wake(vid);
    }

    current_score
}

/// Classic L_{R-P} row update (eqs. 6-7) used by the E5 ablation.
#[inline]
fn classic_update_row(row: &mut [f32], i: usize, sig: Signal, alpha: f32, beta: f32) {
    let m = row.len();
    match sig {
        Signal::Reward => {
            for j in 0..m {
                if j == i {
                    row[j] += alpha * (1.0 - row[j]);
                } else {
                    row[j] *= 1.0 - alpha;
                }
            }
        }
        Signal::Penalty => {
            let spread = beta / (m as f32 - 1.0);
            for j in 0..m {
                if j == i {
                    row[j] *= 1.0 - beta;
                } else {
                    row[j] = row[j] * (1.0 - beta) + spread;
                }
            }
        }
    }
}

/// XLA-engine phase-B body for one batch of the work list (`batch[i]`'s
/// selected action is `cs.selected[pos + i]`): scores through the
/// `score` artifact, migration host-side, LA updates through the
/// `la_update` artifact. Numerically equivalent to the native path
/// (asserted in integration tests), including the frontier-mode
/// isolated-vertex skip.
#[allow(clippy::too_many_arguments)]
fn xla_batch(
    ctx: &StepCtx<'_>,
    cs: &mut ChunkState,
    slab: &ProbSlab,
    eng: &mut XlaStepEngine,
    batch: &[VertexId],
    pos: usize,
    rng: &mut Rng,
    migrations: &mut u64,
) -> f64 {
    let k = cs.k;
    let len = batch.len();
    debug_assert!(len <= BATCH);
    let g = ctx.graph;
    let state = ctx.state;
    let skip = |vid: VertexId| ctx.frontier_on() && g.neighbors(vid).is_empty();

    // Gather histograms host-side (irregular CSR work stays on L3).
    let mut hist = vec![0.0f32; BATCH * k];
    let mut wsum = vec![0.0f32; BATCH];
    for (i, &vid) in batch.iter().enumerate() {
        wsum[i] = neighbor_histogram(
            g.neighbors(vid),
            g.neighbor_weights(vid),
            |u| ctx.label(u),
            &mut hist[i * k..(i + 1) * k],
        );
    }
    // Padded rows keep wsum=1 to avoid 0/0 in the kernel (scores unused).
    for w in wsum[len..].iter_mut() {
        *w = 1.0;
    }

    // L1 kernel: scores (B, k). The penalty term normalizes against the
    // system-level capacity (see PartitionState::system_capacity).
    let scores = eng
        .score(&hist, &wsum, &cs.loads, state.system_capacity() as f32)
        .expect("XLA score execution failed");

    let mut score_sum = 0.0f64;
    let mut raw_w = vec![0.0f32; BATCH * k];
    let mut probs = vec![0.0f32; BATCH * k];
    for (i, &vid) in batch.iter().enumerate() {
        let srow = &scores[i * k..(i + 1) * k];
        // Raw-weight and probability rows must exist for the fixed-shape
        // kernel even when the vertex is skipped (its update is simply
        // never copied back) — a skipped row keeps the all-zero raw
        // vector, exactly like the pad rows past `len`.
        let wrow = &mut raw_w[i * k..(i + 1) * k];
        // SAFETY: exclusive row access per the engine's disjoint work
        // lists.
        unsafe { slab.read_row(vid as usize, &mut probs[i * k..(i + 1) * k]) };
        if skip(vid) {
            // Same semantics as `native_vertex`'s frontier fast path:
            // no publish, no migration, no LA update, score 0, no wake.
            continue;
        }
        let best = argmax(srow);
        ctx.publish(vid, best as u32);

        let action = cs.selected[pos + i];
        let current = state.label(vid);
        if action != current
            && (srow[action as usize] >= srow[current as usize]
                || state.remaining(current as usize) < 0.0)
        {
            let p = ctx.demand.migration_probability(state, action as usize);
            if p > 0.0 && rng.next_f64() < p {
                ctx.migrate(vid, action, g.load_mass(vid));
                *migrations += 1;
            }
        }
        // Convergence signal: score of the post-migration assignment
        // (matches `native_vertex`).
        score_sum += srow[state.label(vid) as usize] as f64;

        // Raw weights (§IV-C step 4 + eq. 13), same arithmetic as
        // `native_vertex`: the modulation accumulates into the zeroed
        // `wrow` (the overlay), then the score base is added on top —
        // f32 addition commutes, so `overlay + score` here is bitwise
        // `score + overlay` there.
        let wsum_inv = if wsum[i] > 1e-12 { 1.0 / wsum[i] } else { 0.0 };
        if wsum_inv > 0.0 {
            for (&u, &w_uv) in g.neighbors(vid).iter().zip(g.neighbor_weights(vid)) {
                let lu = ctx.published(u) as usize;
                if lu == action as usize {
                    wrow[lu] += w_uv * wsum_inv;
                } else if cs.headroom[lu] {
                    wrow[lu] += wsum_inv;
                }
            }
        }
        for (wj, &sj) in wrow.iter_mut().zip(srow.iter()) {
            *wj = sj + *wj;
        }
        // Unsettled self-wake (off-argmax or over-capacity drain
        // pending), matching `native_vertex`.
        let post = state.label(vid);
        if post != best as u32 || state.remaining(post as usize) < 0.0 {
            ctx.wake(vid);
        }
    }
    // Pad rows beyond `len` with uniform distributions (the artifact has
    // a fixed batch dimension).
    for i in len..BATCH {
        WeightedLa::init(&mut probs[i * k..(i + 1) * k]);
    }

    // L1 kernel: signal construction + weighted LA update (B, k).
    let p_next = eng.la_update(&probs, &raw_w).expect("XLA la_update failed");
    for (i, &vid) in batch.iter().enumerate() {
        if skip(vid) {
            continue; // frontier-settled: LA row stays frozen
        }
        // SAFETY: exclusive row access (see above).
        unsafe { slab.write_row(vid as usize, &p_next[i * k..(i + 1) * k]) };
    }
    score_sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Schedule;
    use crate::graph::gen::{generate_dataset, Dataset};
    use crate::metrics::quality;

    fn small_cfg(k: usize) -> RevolverConfig {
        RevolverConfig {
            parts: k,
            max_steps: 60,
            threads: 2,
            seed: 11,
            ..Default::default()
        }
    }

    #[test]
    fn beats_hash_on_social_local_edges() {
        let g = generate_dataset(Dataset::Lj, 2048, 1).unwrap();
        let out = Revolver::new(small_cfg(4)).partition(&g);
        let le = quality::local_edges(&g, &out.labels);
        let hash_le = quality::local_edges(
            &g,
            &super::super::hash::HashPartitioner::new(4).partition(&g).labels,
        );
        assert!(le > hash_le + 0.1, "revolver={le} hash={hash_le}");
    }

    #[test]
    fn balanced_within_epsilon_margin() {
        // The paper's headline: max normalized load stays near 1+ε.
        let g = generate_dataset(Dataset::Lj, 2048, 2).unwrap();
        let out = Revolver::new(small_cfg(8)).partition(&g);
        let mnl = quality::max_normalized_load(&g, &out.labels, 8);
        assert!(mnl < 1.15, "mnl={mnl}");
    }

    #[test]
    fn labels_valid() {
        let g = generate_dataset(Dataset::So, 512, 3).unwrap();
        let out = Revolver::new(small_cfg(8)).partition(&g);
        assert_eq!(out.labels.len(), 512);
        assert!(out.labels.iter().all(|&l| l < 8));
    }

    #[test]
    fn deterministic_single_thread() {
        let g = generate_dataset(Dataset::Wiki, 512, 4).unwrap();
        let mut cfg = small_cfg(4);
        cfg.threads = 1;
        cfg.max_steps = 20;
        let a = Revolver::new(cfg.clone()).partition(&g);
        let b = Revolver::new(cfg).partition(&g);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn schedule_is_bitwise_irrelevant_at_one_thread() {
        // With a single worker both schedules degenerate to the same
        // 0..n chunk, so results must be bit-identical.
        let g = generate_dataset(Dataset::Lj, 512, 9).unwrap();
        let mut cfg = small_cfg(4);
        cfg.threads = 1;
        cfg.max_steps = 15;
        let vertex = Revolver::new(cfg.clone()).partition(&g);
        cfg.schedule = Schedule::Degree;
        let degree = Revolver::new(cfg).partition(&g);
        assert_eq!(vertex.labels, degree.labels);
    }

    #[test]
    fn degree_schedule_multithreaded_valid_and_balanced() {
        let g = generate_dataset(Dataset::Lj, 2048, 5).unwrap();
        let mut cfg = small_cfg(8);
        cfg.threads = 4;
        cfg.schedule = Schedule::Degree;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 8));
        let mnl = quality::max_normalized_load(&g, &out.labels, 8);
        assert!(mnl < 1.15, "mnl={mnl}");
    }

    #[test]
    fn frontier_skips_evaluations_at_fixed_budget() {
        use crate::config::Frontier;
        let g = generate_dataset(Dataset::Lj, 2048, 8).unwrap();
        let steps = 25u32;
        let mut cfg = small_cfg(4);
        cfg.threads = 1;
        cfg.max_steps = steps;
        cfg.halt_window = u32::MAX;
        cfg.frontier = Frontier::Off;
        let off = Revolver::new(cfg.clone()).partition(&g);
        assert_eq!(off.trace.total_evaluated, steps as u64 * 2048);
        cfg.frontier = Frontier::On;
        let on = Revolver::new(cfg).partition(&g);
        assert!(
            on.trace.total_evaluated < off.trace.total_evaluated,
            "on={} off={}",
            on.trace.total_evaluated,
            off.trace.total_evaluated
        );
        assert!(on.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn sync_mode_runs() {
        let g = generate_dataset(Dataset::So, 512, 5).unwrap();
        let mut cfg = small_cfg(4);
        cfg.execution = ExecutionModel::Synchronous;
        cfg.max_steps = 20;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn classic_la_ablation_runs() {
        let g = generate_dataset(Dataset::So, 512, 6).unwrap();
        let mut cfg = small_cfg(4);
        cfg.classic_la = true;
        cfg.max_steps = 20;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn warm_row_is_normalized_and_biased() {
        for k in [2usize, 8, 32] {
            let mut row = vec![0.0f32; k];
            init_warm_row(&mut row, k / 2);
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "k={k} sum={sum}");
            let uniform = 1.0 / k as f32;
            assert!(row[k / 2] > uniform, "k={k}");
            for (i, &p) in row.iter().enumerate() {
                if i != k / 2 {
                    assert!(p > 0.0 && p < uniform, "k={k} i={i} p={p}");
                }
            }
        }
    }

    #[test]
    fn q16_slab_roundtrip_update_and_spin() {
        use crate::util::rng::Rng;
        let k = 8;
        let mut slab = ProbSlab::new(4, k, None, ProbFormat::Q16);
        // Uniform init survives the quantize/dequantize round-trip to
        // within half a q16 step.
        for &p in &slab.row_vec(2) {
            assert!((p - 0.125).abs() < 0.5 / 65535.0, "p={p}");
        }
        // Rewarding one action drives its (quantized) mass up exactly
        // like the f32 slab does.
        let mut w = vec![1.0 / (k as f32 - 1.0); k];
        let mut s = vec![Signal::Penalty; k];
        w[3] = 1.0;
        s[3] = Signal::Reward;
        let mut scratch = vec![0.0f32; k];
        for _ in 0..30 {
            slab.update_row_mut(2, &mut scratch, &w, &s, 0.5, 0.1);
        }
        let row = slab.row_vec(2);
        assert!(row[3] > 0.8, "row={row:?}");
        // Untouched rows stay uniform; draws stay in range and favour
        // the trained action on the trained row.
        assert!((slab.row_vec(1)[3] - 0.125).abs() < 0.5 / 65535.0);
        let mut rng = Rng::new(7);
        let mut hot = 0;
        for _ in 0..200 {
            let a = slab.spin_mut(2, &mut rng);
            assert!(a < k);
            hot += (a == 3) as u32;
        }
        assert!(hot > 120, "hot={hot}");
    }

    #[test]
    fn q16_format_runs_and_balances() {
        let g = generate_dataset(Dataset::Lj, 2048, 6).unwrap();
        let mut cfg = small_cfg(4);
        cfg.prob_format = ProbFormat::Q16;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 4));
        let mnl = quality::max_normalized_load(&g, &out.labels, 4);
        assert!(mnl < 1.15, "mnl={mnl}");
    }

    // The warm-vs-cold convergence assertion (stream:fennel init
    // reaches the halting threshold in <= the steps of random init)
    // lives in tests/integration.rs at acceptance scale.

    #[test]
    fn slab_dump_and_restore_are_bit_identical() {
        use crate::util::rng::Rng;
        let (n, k) = (16usize, 4usize);
        for format in [ProbFormat::F32, ProbFormat::Q16] {
            // Train a few rows so the slab is not trivially uniform.
            let mut slab = ProbSlab::new(n, k, None, format);
            let mut w = vec![0.25f32; k];
            let mut s = vec![Signal::Penalty; k];
            w[1] = 1.0;
            s[1] = Signal::Reward;
            let mut scratch = vec![0.0f32; k];
            for v in 0..n / 2 {
                for _ in 0..5 {
                    slab.update_row_mut(v, &mut scratch, &w, &s, 0.4, 0.1);
                }
            }
            let snap = slab.dump();
            assert_eq!(snap.rows(), n);
            assert_eq!(snap.cols() as usize, k);
            let mut back = ProbSlab::from_checkpoint(n, k, &snap).expect("shape matches");
            for v in 0..n {
                assert_eq!(
                    slab.row_vec(v),
                    back.row_vec(v),
                    "row {v} must survive dump/restore bit-identically"
                );
            }
            // Draws from the restored slab match the original exactly.
            let (mut ra, mut rb) = (Rng::new(9), Rng::new(9));
            for v in 0..n {
                assert_eq!(slab.spin_mut(v, &mut ra), back.spin_mut(v, &mut rb));
            }
            // Shape mismatches degrade to None, never a bogus slab.
            assert!(ProbSlab::from_checkpoint(n + 1, k, &snap).is_none());
            assert!(ProbSlab::from_checkpoint(n, k + 1, &snap).is_none());
        }
    }

    #[test]
    fn trace_enabled_records_improvement() {
        let g = generate_dataset(Dataset::Lj, 1024, 7).unwrap();
        let mut cfg = small_cfg(4);
        cfg.trace_every = 1;
        cfg.max_steps = 40;
        cfg.halt_window = 1000;
        // Full sweeps: the point-count floor below assumes no
        // empty-frontier early halt.
        cfg.frontier = crate::config::Frontier::Off;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.trace.points.len() >= 30);
        let first = out.trace.points.first().unwrap().local_edges;
        let last = out.trace.points.last().unwrap().local_edges;
        assert!(last > first, "local edges should improve: {first} -> {last}");
    }
}
