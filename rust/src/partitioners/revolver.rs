//! Revolver — the paper's contribution (§IV): asynchronous vertex-centric
//! partitioning where each vertex's **weighted learning automaton** picks
//! its partition and is trained by the **normalized LP** objective.
//!
//! Step structure (§IV-D, Figure 2):
//!  1. every LA draws an action (candidate partition) — roulette wheel;
//!  2. candidates register migration *demand* m(l);
//!  3. normalized LP scores (eqs. 10–12) are computed per vertex and the
//!     argmax label λ(v) is published for neighbours;
//!  4. the vertex migrates to its selected action with probability
//!     min(1, r(l)/m(l)) when the action differs from its current label;
//!  5. raw weights are accumulated from neighbour λ's (eq. 13);
//!  6. the weight vector is mean-split into reward/penalty halves and
//!     half-normalized (§IV-D.6);
//!  7. the LA probability vector is updated (eqs. 8–9);
//!  8. convergence: halt after `halt_window` consecutive sub-θ steps.
//!
//! **Asynchronous** mode (the paper's headline implementation) reads
//! labels, loads and λ's live from shared atomics — workers see each
//! other's migrations mid-step ("progressively exchanged loads",
//! §V-H.2). **Synchronous** mode (ablation E4) freezes label/λ/load
//! snapshots per step, Giraph-style.
//!
//! Threading: `threads` persistent workers (one per contiguous vertex
//! chunk, the paper's |V|/n layout) synchronized by a barrier protocol —
//! three barriers per step (step-start, post-action/demand, step-end).
//! Persistent workers matter for two reasons: no thread-spawn cost in
//! the 290-step loop, and the PJRT executable handles (`--engine xla`)
//! are `!Send`, so each worker constructs and owns its own engine.
//!
//! Eq. (13) note: the printed equation mixes λ(v)/λ(u) and ψ indices
//! inconsistently; we implement the reading consistent with §IV-C step 4
//! ("scores … are evaluated by (13) to form the weight vector W"): the
//! raw weight vector starts from the vertex's own score vector, and each
//! neighbour u endorses partition λ(u) with ŵ(u,v)/Σŵ when v's selected
//! action agrees, else 1/Σŵ while λ(u) has migration headroom. DESIGN.md
//! §Fidelity-notes (F5–F7) records this and the other disambiguations.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};

use super::{PartitionOutput, Partitioner};
use crate::config::{Engine, ExecutionModel, RevolverConfig};
use crate::coordinator::{Chunks, ConvergenceDetector};
use crate::graph::Graph;
use crate::la::signal::build_signals_into;
use crate::la::weighted::WeightedLa;
use crate::la::{roulette, Signal};
use crate::lp::{neighbor_histogram, normalized as nlp};
use crate::metrics::quality;
use crate::metrics::trace::{RunTrace, TracePoint};
use crate::partition::{DemandTracker, InitialAssignment, PartitionState};
use crate::runtime::XlaStepEngine;
use crate::util::rng::Rng;
use crate::util::Stopwatch;
use crate::VertexId;

/// How many vertices share one load/π snapshot in the scoring loop (and
/// one XLA batch in `--engine xla`; must match the artifact batch dim).
pub const BATCH: usize = 256;

pub struct Revolver {
    cfg: RevolverConfig,
}

impl Revolver {
    pub fn new(cfg: RevolverConfig) -> Self {
        cfg.validate().expect("invalid config");
        Revolver { cfg }
    }

    /// Access the effective configuration.
    pub fn config(&self) -> &RevolverConfig {
        &self.cfg
    }
}

/// Per-worker mutable state: the probability slab for the chunk's
/// vertices plus all scratch buffers, so the hot loop never allocates.
struct ChunkState {
    /// Flat (chunk_len × k) probability rows.
    probs: Vec<f32>,
    start: usize,
    k: usize,
    // Scratch (k-sized).
    hist: Vec<f32>,
    scores: Vec<f32>,
    pi: Vec<f32>,
    raw_w: Vec<f32>,
    w_norm: Vec<f32>,
    signals: Vec<Signal>,
    loads: Vec<f32>,
    /// Per-batch precomputed "partition still has migration headroom"
    /// flags — replaces two atomic loads per neighbour in the eq.-(13)
    /// accumulation (perf log P3).
    headroom: Vec<bool>,
}

impl ChunkState {
    fn new(range: std::ops::Range<usize>, k: usize) -> Self {
        let len = range.len();
        let mut probs = vec![0.0f32; len * k];
        for row in probs.chunks_mut(k) {
            WeightedLa::init(row);
        }
        ChunkState {
            probs,
            start: range.start,
            k,
            hist: vec![0.0; k],
            scores: vec![0.0; k],
            pi: vec![0.0; k],
            raw_w: vec![0.0; k],
            w_norm: vec![0.0; k],
            signals: vec![Signal::Penalty; k],
            loads: vec![0.0; k],
            headroom: vec![true; k],
        }
    }

    #[inline]
    fn row_range(&self, v: usize) -> std::ops::Range<usize> {
        let i = (v - self.start) * self.k;
        i..i + self.k
    }
}

/// Per-step frozen snapshots for the synchronous execution model
/// (empty vectors in asynchronous mode).
#[derive(Default)]
struct StepSnapshots {
    labels: Vec<u32>,
    lambda: Vec<u32>,
}

impl Partitioner for Revolver {
    fn name(&self) -> &'static str {
        "revolver"
    }

    fn partition(&self, g: &Graph) -> PartitionOutput {
        let sw = Stopwatch::start();
        let cfg = &self.cfg;
        let k = cfg.parts;
        let n = g.num_vertices();
        let sync = cfg.execution == ExecutionModel::Synchronous;

        let state =
            PartitionState::new(g, k, cfg.epsilon, InitialAssignment::Random(cfg.seed));
        let chunks = Chunks::new(n, cfg.threads);
        let t = chunks.len();
        let base_rng = Rng::new(cfg.seed ^ 0x5245564F); // "REVO"

        // λ(v): the argmax-score label each vertex publishes (§IV-D.3),
        // initialized to the starting labels.
        let lambda: Vec<AtomicU32> =
            (0..n).map(|v| AtomicU32::new(state.label(v as u32))).collect();
        // The action each LA selected this step.
        let selected: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let demand = DemandTracker::new(k);

        // Probe the XLA engine on the main thread first: a worker panic
        // behind the barrier protocol would deadlock the coordinator, so
        // surface configuration errors (missing artifacts, wrong k,
        // mismatched alpha/beta) eagerly and cleanly here.
        if cfg.engine == Engine::Xla {
            XlaStepEngine::load(&cfg.artifacts_dir, BATCH, k, cfg.alpha, cfg.beta)
                .expect("failed to load XLA artifacts (run `make artifacts`)");
        }

        let barrier = Barrier::new(t + 1);
        let stop = AtomicBool::new(false);
        let snapshots: Mutex<Arc<StepSnapshots>> =
            Mutex::new(Arc::new(StepSnapshots::default()));
        let score_parts: Vec<AtomicU64> = (0..t).map(|_| AtomicU64::new(0)).collect();
        let migration_parts: Vec<AtomicU64> = (0..t).map(|_| AtomicU64::new(0)).collect();

        let mut detector = ConvergenceDetector::new(cfg.halt_theta, cfg.halt_window);
        let mut trace = RunTrace::default();
        let mut executed_steps: u32 = 0;

        crossbeam_utils::thread::scope(|scope| {
            // ── Workers ──
            for c in 0..t {
                let range = chunks.range(c);
                let (g, state, demand, lambda, selected) =
                    (&g, &state, &demand, &lambda, &selected);
                let (barrier, stop, snapshots) = (&barrier, &stop, &snapshots);
                let (score_parts, migration_parts) = (&score_parts, &migration_parts);
                let base_rng = base_rng.clone();
                scope.spawn(move |_| {
                    let mut cs = ChunkState::new(range.clone(), k);
                    // PJRT handles are !Send: construct inside the worker.
                    let mut eng: Option<XlaStepEngine> = match cfg.engine {
                        Engine::Xla => Some(
                            XlaStepEngine::load(
                                &cfg.artifacts_dir,
                                BATCH,
                                k,
                                cfg.alpha,
                                cfg.beta,
                            )
                            .expect("failed to load XLA artifacts (run `make artifacts`)"),
                        ),
                        Engine::Native => None,
                    };
                    let mut step: u64 = 0;
                    loop {
                        barrier.wait(); // W1: step start (main prepared)
                        if stop.load(Ordering::Acquire) {
                            break;
                        }
                        let snap = snapshots.lock().unwrap().clone();

                        // ── Phase A: action selection + demand (§IV-D.1/2) ──
                        let mut rng = base_rng.fork(step * 2 * t as u64 + c as u64);
                        for v in range.clone() {
                            let row = &cs.probs[cs.row_range(v)];
                            let a = roulette::spin(row, &mut rng) as u32;
                            selected[v].store(a, Ordering::Relaxed);
                            if a != state.label(v as VertexId) {
                                demand.add(a as usize, g.out_degree(v as VertexId));
                            }
                        }
                        barrier.wait(); // W2: all demand registered

                        // ── Phase B: score, λ, migrate, learn (§IV-D.3–7) ──
                        let mut rng =
                            base_rng.fork((step * 2 + 1) * t as u64 + c as u64);
                        let mut score_sum = 0.0f64;
                        let mut migrations = 0u64;
                        let mut batch_start = range.start;
                        while batch_start < range.end {
                            let batch_end = (batch_start + BATCH).min(range.end);
                            // One load/π snapshot per batch (async
                            // staleness tolerance; exactly the artifact's
                            // granularity).
                            state.loads_into(&mut cs.loads);
                            nlp::penalty_into(
                                &cs.loads,
                                state.system_capacity() as f32,
                                &mut cs.pi,
                            );
                            let cap = state.capacity() as f32;
                            for l in 0..k {
                                cs.headroom[l] =
                                    demand.get(l) <= 0 || cs.loads[l] < cap;
                            }
                            match eng.as_mut() {
                                Some(eng) => {
                                    score_sum += xla_batch(
                                        g,
                                        &mut cs,
                                        eng,
                                        batch_start..batch_end,
                                        state,
                                        demand,
                                        lambda,
                                        selected,
                                        &snap,
                                        sync,
                                        &mut rng,
                                        &mut migrations,
                                        cfg,
                                    );
                                }
                                None => {
                                    for v in batch_start..batch_end {
                                        score_sum += native_vertex(
                                            g,
                                            &mut cs,
                                            v,
                                            state,
                                            demand,
                                            lambda,
                                            selected,
                                            &snap,
                                            sync,
                                            &mut rng,
                                            &mut migrations,
                                            cfg,
                                        );
                                    }
                                }
                            }
                            batch_start = batch_end;
                        }
                        score_parts[c].store(score_sum.to_bits(), Ordering::Relaxed);
                        migration_parts[c].store(migrations, Ordering::Relaxed);
                        barrier.wait(); // W3: step done; main aggregates
                        step += 1;
                    }
                });
            }

            // ── Coordinator (main thread) ──
            let executed_steps = &mut executed_steps;
            for step in 0..cfg.max_steps {
                *executed_steps = step + 1;
                demand.reset();
                if sync {
                    *snapshots.lock().unwrap() = Arc::new(StepSnapshots {
                        labels: state.labels_snapshot(),
                        lambda: lambda.iter().map(|l| l.load(Ordering::Relaxed)).collect(),
                    });
                }
                barrier.wait(); // W1
                barrier.wait(); // W2
                barrier.wait(); // W3

                let mean_score = score_parts
                    .iter()
                    .map(|s| f64::from_bits(s.load(Ordering::Relaxed)))
                    .sum::<f64>()
                    / n as f64;
                let migrations: u64 =
                    migration_parts.iter().map(|m| m.load(Ordering::Relaxed)).sum();

                if cfg.trace_every > 0 && step % cfg.trace_every == 0 {
                    let labels = state.labels_snapshot();
                    trace.push(TracePoint {
                        step,
                        local_edges: quality::local_edges(g, &labels),
                        max_normalized_load: quality::max_normalized_load(g, &labels, k),
                        mean_score,
                        migrations,
                    });
                }

                if detector.observe(mean_score) {
                    trace.converged_at = Some(step);
                    break;
                }
            }
            stop.store(true, Ordering::Release);
            barrier.wait(); // release workers into the stop check
        })
        .expect("revolver worker panicked");

        let labels = state.labels_snapshot();
        debug_assert!(state.check_load_invariant().is_ok());
        if trace.points.is_empty() || cfg.trace_every == 0 {
            let q = quality::evaluate(g, &labels, k);
            trace.push(TracePoint {
                step: executed_steps.max(1) - 1,
                local_edges: q.local_edges,
                max_normalized_load: q.max_normalized_load,
                mean_score: 0.0,
                migrations: 0,
            });
        }
        trace.wall_time_s = sw.elapsed_s();
        PartitionOutput { labels, trace }
    }
}

#[inline]
fn read_label(state: &PartitionState, snap: &StepSnapshots, sync: bool, u: u32) -> u32 {
    if sync {
        snap.labels[u as usize]
    } else {
        state.label(u)
    }
}

#[inline]
fn read_lambda(lambda: &[AtomicU32], snap: &StepSnapshots, sync: bool, u: u32) -> u32 {
    if sync {
        snap.lambda[u as usize]
    } else {
        lambda[u as usize].load(Ordering::Relaxed)
    }
}

/// Native per-vertex phase-B body. Returns the vertex's best score
/// (its contribution to the convergence signal S).
#[allow(clippy::too_many_arguments)]
#[inline]
fn native_vertex(
    g: &Graph,
    cs: &mut ChunkState,
    v: usize,
    state: &PartitionState,
    demand: &DemandTracker,
    lambda: &[AtomicU32],
    selected: &[AtomicU32],
    snap: &StepSnapshots,
    sync: bool,
    rng: &mut Rng,
    migrations: &mut u64,
    cfg: &RevolverConfig,
) -> f64 {
    let vid = v as VertexId;

    // 3. Normalized LP scores + λ(v) (eqs. 10-12).
    let wsum = neighbor_histogram(
        g.neighbors(vid),
        g.neighbor_weights(vid),
        |u| read_label(state, snap, sync, u),
        &mut cs.hist,
    );
    let best = nlp::score_into(&cs.hist, wsum, &cs.pi, &mut cs.scores);
    lambda[v].store(best as u32, Ordering::Relaxed);

    // 4. Migration (§IV-D.4): move to the sampled action when it beats
    // the current partition's score (the Spinner-candidate analogue —
    // Spinner also never migrates to a lower-score partition) and the
    // capacity gate admits it. Vertices sitting in an *over-capacity*
    // partition may leave unconditionally — draining b(l) > C back
    // under the eq. (1) bound takes precedence over locality.
    let action = selected[v].load(Ordering::Relaxed);
    let current = state.label(vid);
    if action != current
        && (cs.scores[action as usize] >= cs.scores[current as usize]
            || state.remaining(current as usize) < 0.0)
    {
        let p = demand.migration_probability(state, action as usize);
        if p > 0.0 && rng.next_f64() < p {
            state.migrate(vid, action, g.out_degree(vid));
            *migrations += 1;
        }
    }
    // Convergence signal S: the score of the vertex's (post-migration)
    // assignment — the same global objective Spinner's halting check
    // uses; the *best* score is a noisy constant on small graphs while
    // this tracks actual assignment quality.
    let current_score = cs.scores[state.label(vid) as usize] as f64;

    // 5. Raw weights (§IV-C step 4 + eq. 13): start from the normalized
    // LP scores ("scores generated from multiple passes of (10) are
    // evaluated by (13) to form the weight vector W") and add the
    // τ-normalized neighbour-preference modulation — neighbour u
    // endorses partition λ(u) with ŵ(u,v)/Σŵ when v's action agrees,
    // else with 1/Σŵ while λ(u) still has migration headroom.
    cs.raw_w.copy_from_slice(&cs.scores);
    let wsum_inv = if wsum > 1e-12 { 1.0 / wsum } else { 0.0 };
    for (&u, &w_uv) in g.neighbors(vid).iter().zip(g.neighbor_weights(vid)) {
        let lu = read_lambda(lambda, snap, sync, u) as usize;
        if lu == action as usize {
            cs.raw_w[lu] += w_uv * wsum_inv;
        } else if cs.headroom[lu] {
            cs.raw_w[lu] += wsum_inv;
        }
    }

    // 6+7. Signals + LA update (§IV-D.6/7).
    let rr = cs.row_range(v);
    if cfg.classic_la {
        // Ablation E5: classic single-action update (eqs. 6-7) — reward
        // the selected action iff it matches λ(v).
        let sig = if action as usize == best { Signal::Reward } else { Signal::Penalty };
        classic_update_row(&mut cs.probs[rr], action as usize, sig, cfg.alpha, cfg.beta);
    } else {
        build_signals_into(&cs.raw_w, &mut cs.w_norm, &mut cs.signals);
        // `probs` and the scratch vectors are distinct fields; split the
        // borrows explicitly.
        let ChunkState { probs, w_norm, signals, .. } = cs;
        WeightedLa::update(&mut probs[rr], w_norm, signals, cfg.alpha, cfg.beta);
    }

    current_score
}

/// Classic L_{R-P} row update (eqs. 6-7) used by the E5 ablation.
#[inline]
fn classic_update_row(row: &mut [f32], i: usize, sig: Signal, alpha: f32, beta: f32) {
    let m = row.len();
    match sig {
        Signal::Reward => {
            for j in 0..m {
                if j == i {
                    row[j] += alpha * (1.0 - row[j]);
                } else {
                    row[j] *= 1.0 - alpha;
                }
            }
        }
        Signal::Penalty => {
            let spread = beta / (m as f32 - 1.0);
            for j in 0..m {
                if j == i {
                    row[j] *= 1.0 - beta;
                } else {
                    row[j] = row[j] * (1.0 - beta) + spread;
                }
            }
        }
    }
}

/// XLA-engine phase-B body for one batch: scores through the `score`
/// artifact, migration host-side, LA updates through the `la_update`
/// artifact. Numerically equivalent to the native path (asserted in
/// integration tests).
#[allow(clippy::too_many_arguments)]
fn xla_batch(
    g: &Graph,
    cs: &mut ChunkState,
    eng: &mut XlaStepEngine,
    range: std::ops::Range<usize>,
    state: &PartitionState,
    demand: &DemandTracker,
    lambda: &[AtomicU32],
    selected: &[AtomicU32],
    snap: &StepSnapshots,
    sync: bool,
    rng: &mut Rng,
    migrations: &mut u64,
    cfg: &RevolverConfig,
) -> f64 {
    let k = cs.k;
    let len = range.len();
    debug_assert!(len <= BATCH);
    let _ = cfg;

    // Gather histograms host-side (irregular CSR work stays on L3).
    let mut hist = vec![0.0f32; BATCH * k];
    let mut wsum = vec![0.0f32; BATCH];
    for (i, v) in range.clone().enumerate() {
        let vid = v as VertexId;
        wsum[i] = neighbor_histogram(
            g.neighbors(vid),
            g.neighbor_weights(vid),
            |u| read_label(state, snap, sync, u),
            &mut hist[i * k..(i + 1) * k],
        );
    }
    // Padded rows keep wsum=1 to avoid 0/0 in the kernel (scores unused).
    for w in wsum[len..].iter_mut() {
        *w = 1.0;
    }

    // L1 kernel: scores (B, k). The penalty term normalizes against the
    // system-level capacity (see PartitionState::system_capacity).
    let scores = eng
        .score(&hist, &wsum, &cs.loads, state.system_capacity() as f32)
        .expect("XLA score execution failed");

    let mut score_sum = 0.0f64;
    let mut raw_w = vec![0.0f32; BATCH * k];
    let mut probs = vec![0.0f32; BATCH * k];
    for (i, v) in range.clone().enumerate() {
        let vid = v as VertexId;
        let srow = &scores[i * k..(i + 1) * k];
        let mut best = 0usize;
        let mut best_s = f32::NEG_INFINITY;
        for (l, &s) in srow.iter().enumerate() {
            if s > best_s {
                best_s = s;
                best = l;
            }
        }
        lambda[v].store(best as u32, Ordering::Relaxed);
        let _ = best_s;

        let action = selected[v].load(Ordering::Relaxed);
        let current = state.label(vid);
        if action != current
            && (srow[action as usize] >= srow[current as usize]
                || state.remaining(current as usize) < 0.0)
        {
            let p = demand.migration_probability(state, action as usize);
            if p > 0.0 && rng.next_f64() < p {
                state.migrate(vid, action, g.out_degree(vid));
                *migrations += 1;
            }
        }
        // Convergence signal: score of the post-migration assignment
        // (matches `native_vertex`).
        score_sum += srow[state.label(vid) as usize] as f64;

        // Raw weights (§IV-C step 4 + eq. 13), same semantics as
        // `native_vertex`.
        let wrow = &mut raw_w[i * k..(i + 1) * k];
        wrow.copy_from_slice(srow);
        let wsum_inv = if wsum[i] > 1e-12 { 1.0 / wsum[i] } else { 0.0 };
        for (&u, &w_uv) in g.neighbors(vid).iter().zip(g.neighbor_weights(vid)) {
            let lu = read_lambda(lambda, snap, sync, u) as usize;
            if lu == action as usize {
                wrow[lu] += w_uv * wsum_inv;
            } else if cs.headroom[lu] {
                wrow[lu] += wsum_inv;
            }
        }
        probs[i * k..(i + 1) * k].copy_from_slice(&cs.probs[cs.row_range(v)]);
    }
    // Pad rows beyond `len` with uniform distributions (the artifact has
    // a fixed batch dimension).
    for i in len..BATCH {
        WeightedLa::init(&mut probs[i * k..(i + 1) * k]);
    }

    // L1 kernel: signal construction + weighted LA update (B, k).
    let p_next = eng.la_update(&probs, &raw_w).expect("XLA la_update failed");
    for (i, v) in range.enumerate() {
        let rr = cs.row_range(v);
        cs.probs[rr].copy_from_slice(&p_next[i * k..(i + 1) * k]);
    }
    score_sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_dataset, Dataset};

    fn small_cfg(k: usize) -> RevolverConfig {
        RevolverConfig {
            parts: k,
            max_steps: 60,
            threads: 2,
            seed: 11,
            ..Default::default()
        }
    }

    #[test]
    fn beats_hash_on_social_local_edges() {
        let g = generate_dataset(Dataset::Lj, 2048, 1).unwrap();
        let out = Revolver::new(small_cfg(4)).partition(&g);
        let le = quality::local_edges(&g, &out.labels);
        let hash_le = quality::local_edges(
            &g,
            &super::super::hash::HashPartitioner::new(4).partition(&g).labels,
        );
        assert!(le > hash_le + 0.1, "revolver={le} hash={hash_le}");
    }

    #[test]
    fn balanced_within_epsilon_margin() {
        // The paper's headline: max normalized load stays near 1+ε.
        let g = generate_dataset(Dataset::Lj, 2048, 2).unwrap();
        let out = Revolver::new(small_cfg(8)).partition(&g);
        let mnl = quality::max_normalized_load(&g, &out.labels, 8);
        assert!(mnl < 1.15, "mnl={mnl}");
    }

    #[test]
    fn labels_valid() {
        let g = generate_dataset(Dataset::So, 512, 3).unwrap();
        let out = Revolver::new(small_cfg(8)).partition(&g);
        assert_eq!(out.labels.len(), 512);
        assert!(out.labels.iter().all(|&l| l < 8));
    }

    #[test]
    fn deterministic_single_thread() {
        let g = generate_dataset(Dataset::Wiki, 512, 4).unwrap();
        let mut cfg = small_cfg(4);
        cfg.threads = 1;
        cfg.max_steps = 20;
        let a = Revolver::new(cfg.clone()).partition(&g);
        let b = Revolver::new(cfg).partition(&g);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn sync_mode_runs() {
        let g = generate_dataset(Dataset::So, 512, 5).unwrap();
        let mut cfg = small_cfg(4);
        cfg.execution = ExecutionModel::Synchronous;
        cfg.max_steps = 20;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn classic_la_ablation_runs() {
        let g = generate_dataset(Dataset::So, 512, 6).unwrap();
        let mut cfg = small_cfg(4);
        cfg.classic_la = true;
        cfg.max_steps = 20;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn trace_enabled_records_improvement() {
        let g = generate_dataset(Dataset::Lj, 1024, 7).unwrap();
        let mut cfg = small_cfg(4);
        cfg.trace_every = 1;
        cfg.max_steps = 40;
        cfg.halt_window = 1000;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.trace.points.len() >= 30);
        let first = out.trace.points.first().unwrap().local_edges;
        let last = out.trace.points.last().unwrap().local_edges;
        assert!(last > first, "local edges should improve: {first} -> {last}");
    }
}
