//! Revolver — the paper's contribution (§IV): asynchronous vertex-centric
//! partitioning where each vertex's **weighted learning automaton** picks
//! its partition and is trained by the **normalized LP** objective.
//!
//! Step structure (§IV-D, Figure 2):
//!  1. every LA draws an action (candidate partition) — roulette wheel;
//!  2. candidates register migration *demand* m(l);
//!  3. normalized LP scores (eqs. 10–12) are computed per vertex and the
//!     argmax label λ(v) is published for neighbours;
//!  4. the vertex migrates to its selected action with probability
//!     min(1, r(l)/m(l)) when the action differs from its current label;
//!  5. raw weights are accumulated from neighbour λ's (eq. 13);
//!  6. the weight vector is mean-split into reward/penalty halves and
//!     half-normalized (§IV-D.6);
//!  7. the LA probability vector is updated (eqs. 8–9);
//!  8. convergence: halt after `halt_window` consecutive sub-θ steps.
//!
//! **Asynchronous** mode (the paper's headline implementation) reads
//! labels, loads and λ's live from shared atomics — workers see each
//! other's migrations mid-step ("progressively exchanged loads",
//! §V-H.2). **Synchronous** mode (ablation E4) freezes label/λ/load
//! snapshots per step, Giraph-style.
//!
//! Execution is delegated to [`crate::engine`]: steps 1–2 are the
//! engine's phase A, steps 3–7 its phase B, and λ(v) rides the engine's
//! per-vertex *published* channel (so the sync-mode freeze applies to it
//! automatically). This module only contains the per-vertex math; all
//! thread orchestration, snapshotting and halting live in the engine.
//!
//! Eq. (13) note: the printed equation mixes λ(v)/λ(u) and ψ indices
//! inconsistently; we implement the reading consistent with §IV-C step 4
//! ("scores … are evaluated by (13) to form the weight vector W"): the
//! raw weight vector starts from the vertex's own score vector, and each
//! neighbour u endorses partition λ(u) with ŵ(u,v)/Σŵ when v's selected
//! action agrees, else 1/Σŵ while λ(u) has migration headroom. DESIGN.md
//! §Fidelity-notes (F5–F7) records this and the other disambiguations.

use std::ops::Range;

use super::{PartitionOutput, Partitioner};
use crate::config::{Engine, ExecutionModel, RevolverConfig};
use crate::engine::{self, StepCtx, StepStats, VertexProgram};
use crate::graph::Graph;
use crate::la::signal::build_signals_into;
use crate::la::weighted::WeightedLa;
use crate::la::{roulette, Signal};
use crate::lp::{neighbor_histogram, normalized as nlp};
use crate::partition::{DemandTracker, InitialAssignment, PartitionState};
use crate::runtime::XlaStepEngine;
use crate::util::rng::Rng;
use crate::VertexId;

/// How many vertices share one load/π snapshot in the scoring loop (and
/// one XLA batch in `--engine xla`; must match the artifact batch dim).
pub const BATCH: usize = 256;

pub struct Revolver {
    cfg: RevolverConfig,
}

impl Revolver {
    pub fn new(cfg: RevolverConfig) -> Self {
        cfg.validate().expect("invalid config");
        Revolver { cfg }
    }

    /// Access the effective configuration.
    pub fn config(&self) -> &RevolverConfig {
        &self.cfg
    }
}

/// Per-worker mutable state: the probability slab for the chunk's
/// vertices plus all scratch buffers, so the hot loop never allocates.
struct ChunkState {
    /// Flat (chunk_len × k) probability rows.
    probs: Vec<f32>,
    /// The action each of the chunk's LAs selected this step (phase A →
    /// phase B hand-off; only ever read for own vertices, so it lives in
    /// scratch rather than a shared array).
    selected: Vec<u32>,
    start: usize,
    k: usize,
    // Scratch (k-sized).
    hist: Vec<f32>,
    scores: Vec<f32>,
    pi: Vec<f32>,
    raw_w: Vec<f32>,
    w_norm: Vec<f32>,
    signals: Vec<Signal>,
    loads: Vec<f32>,
    /// Per-batch precomputed "partition still has migration headroom"
    /// flags — replaces two atomic loads per neighbour in the eq.-(13)
    /// accumulation (perf log P3).
    headroom: Vec<bool>,
}

/// Warm-start mass on the streamed label: the row starts at
/// `1/k + WARM_BIAS·(1 − 1/k)` there — i.e. halfway between uniform
/// and deterministic — and the remainder spreads evenly, so the LA
/// keeps exploring but no longer burns steps rediscovering the
/// streaming pass's structure.
const WARM_BIAS: f32 = 0.5;

/// Initialize one LA probability row biased toward `warm`.
/// `hot = 0.5·(k+1)/k`, `cold = 0.5/k`; `hot + (k−1)·cold = 1`.
fn init_warm_row(row: &mut [f32], warm: usize) {
    let k = row.len() as f32;
    let hot = 1.0 / k + WARM_BIAS * (1.0 - 1.0 / k);
    let cold = (1.0 - hot) / (k - 1.0);
    row.fill(cold);
    row[warm] = hot;
}

impl ChunkState {
    fn new(range: Range<usize>, k: usize, warm: Option<&[crate::Label]>) -> Self {
        let len = range.len();
        let mut probs = vec![0.0f32; len * k];
        match warm {
            None => {
                for row in probs.chunks_mut(k) {
                    WeightedLa::init(row);
                }
            }
            Some(labels) => {
                for (i, row) in probs.chunks_mut(k).enumerate() {
                    init_warm_row(row, labels[range.start + i] as usize);
                }
            }
        }
        ChunkState {
            probs,
            selected: vec![0; len],
            start: range.start,
            k,
            hist: vec![0.0; k],
            scores: vec![0.0; k],
            pi: vec![0.0; k],
            raw_w: vec![0.0; k],
            w_norm: vec![0.0; k],
            signals: vec![Signal::Penalty; k],
            loads: vec![0.0; k],
            headroom: vec![true; k],
        }
    }

    #[inline]
    fn row_range(&self, v: usize) -> Range<usize> {
        let i = (v - self.start) * self.k;
        i..i + self.k
    }

    #[inline]
    fn selected_of(&self, v: usize) -> u32 {
        self.selected[v - self.start]
    }
}

/// Revolver as a [`VertexProgram`]: phase A draws actions and registers
/// demand, phase B scores/migrates/learns (natively or through the XLA
/// artifacts).
struct RevolverProgram<'a> {
    cfg: &'a RevolverConfig,
    /// Streaming warm-start labels (`--init stream:<algo>`): each
    /// vertex's LA row starts biased toward its label instead of
    /// uniform. `None` = uniform random init (the paper).
    warm: Option<Vec<crate::Label>>,
}

impl VertexProgram for RevolverProgram<'_> {
    type Scratch = (ChunkState, Option<XlaStepEngine>);
    type PhaseA = ();
    type PhaseB = ();

    fn execution(&self) -> ExecutionModel {
        self.cfg.execution
    }

    fn rng_salt(&self) -> u64 {
        0x5245564F // "REVO"
    }

    fn init_published(&self, v: VertexId, state: &PartitionState) -> u32 {
        // λ(v) starts at the initial label.
        state.label(v)
    }

    fn make_scratch(&self, chunk: Range<usize>) -> Self::Scratch {
        // PJRT handles are !Send: construct inside the worker.
        let eng = match self.cfg.engine {
            Engine::Xla => Some(
                XlaStepEngine::load(
                    &self.cfg.artifacts_dir,
                    BATCH,
                    self.cfg.parts,
                    self.cfg.alpha,
                    self.cfg.beta,
                )
                .expect("failed to load XLA artifacts (run `make artifacts`)"),
            ),
            Engine::Native => None,
        };
        (ChunkState::new(chunk, self.cfg.parts, self.warm.as_deref()), eng)
    }

    fn prepare_phase_a(&self, _g: &Graph, _state: &PartitionState, _step: u32) {}

    fn prepare_phase_b(
        &self,
        _g: &Graph,
        _state: &PartitionState,
        _demand: &DemandTracker,
        _step: u32,
    ) {
    }

    fn phase_a(
        &self,
        ctx: &StepCtx<'_>,
        _frozen: &(),
        scratch: &mut Self::Scratch,
        chunk: Range<usize>,
        rng: &mut Rng,
    ) -> StepStats {
        let cs = &mut scratch.0;
        // ── Action selection + demand (§IV-D.1/2) ──
        for v in chunk {
            let row = &cs.probs[cs.row_range(v)];
            let a = roulette::spin(row, rng) as u32;
            cs.selected[v - cs.start] = a;
            if a != ctx.state.label(v as VertexId) {
                ctx.demand.add(a as usize, ctx.graph.load_mass(v as VertexId));
            }
        }
        StepStats::default()
    }

    fn phase_b(
        &self,
        ctx: &StepCtx<'_>,
        _frozen: &(),
        scratch: &mut Self::Scratch,
        chunk: Range<usize>,
        rng: &mut Rng,
    ) -> StepStats {
        let (cs, eng) = scratch;
        let k = cs.k;
        let mut stats = StepStats::default();
        let mut batch_start = chunk.start;
        while batch_start < chunk.end {
            let batch_end = (batch_start + BATCH).min(chunk.end);
            // One load/π snapshot per batch (async staleness tolerance;
            // exactly the artifact's granularity).
            ctx.state.loads_into(&mut cs.loads);
            nlp::penalty_into(&cs.loads, ctx.state.system_capacity() as f32, &mut cs.pi);
            let cap = ctx.state.capacity() as f32;
            for l in 0..k {
                cs.headroom[l] = ctx.demand.get(l) <= 0 || cs.loads[l] < cap;
            }
            match eng.as_mut() {
                Some(eng) => {
                    stats.score_sum += xla_batch(
                        ctx,
                        cs,
                        eng,
                        batch_start..batch_end,
                        rng,
                        &mut stats.migrations,
                    );
                }
                None => {
                    for v in batch_start..batch_end {
                        stats.score_sum +=
                            native_vertex(ctx, cs, v, rng, &mut stats.migrations, self.cfg);
                    }
                }
            }
            batch_start = batch_end;
        }
        stats
    }
}

impl Partitioner for Revolver {
    fn name(&self) -> &'static str {
        "revolver"
    }

    fn partition(&self, g: &Graph) -> PartitionOutput {
        // Probe the XLA engine on the main thread first: a worker panic
        // behind the barrier protocol would deadlock the coordinator, so
        // surface configuration errors (missing artifacts, wrong k,
        // mismatched alpha/beta) eagerly and cleanly here.
        if self.cfg.engine == Engine::Xla {
            XlaStepEngine::load(
                &self.cfg.artifacts_dir,
                BATCH,
                self.cfg.parts,
                self.cfg.alpha,
                self.cfg.beta,
            )
            .expect("failed to load XLA artifacts (run `make artifacts`)");
        }
        // Compute the initial assignment once: the engine seeds the
        // shared labels from it, and (for a streaming warm start) the
        // program biases each LA row toward its vertex's label.
        let init = engine::initial_assignment(g, &self.cfg);
        let warm = match &init {
            InitialAssignment::Given(labels) => Some(labels.clone()),
            _ => None,
        };
        engine::run_with_init(g, &self.cfg, &RevolverProgram { cfg: &self.cfg, warm }, init)
    }
}

/// Run a bounded Revolver pass from an explicit initial assignment —
/// the multilevel V-cycle's per-level refiner. Every LA row starts
/// biased toward its vertex's given label (the same warm start the
/// streaming bridge uses), and on graphs with vertex weights the
/// demand/migration mass is the coarse vertex weight
/// ([`Graph::load_mass`]).
pub fn refine(g: &Graph, cfg: &RevolverConfig, init: Vec<crate::Label>) -> PartitionOutput {
    let program = RevolverProgram { cfg, warm: Some(init.clone()) };
    engine::run_with_init(g, cfg, &program, InitialAssignment::Given(init))
}

/// Native per-vertex phase-B body. Returns the vertex's best score
/// (its contribution to the convergence signal S).
#[inline]
fn native_vertex(
    ctx: &StepCtx<'_>,
    cs: &mut ChunkState,
    v: usize,
    rng: &mut Rng,
    migrations: &mut u64,
    cfg: &RevolverConfig,
) -> f64 {
    let vid = v as VertexId;
    let g = ctx.graph;
    let state = ctx.state;

    // 3. Normalized LP scores + λ(v) (eqs. 10-12).
    let wsum = neighbor_histogram(
        g.neighbors(vid),
        g.neighbor_weights(vid),
        |u| ctx.label(u),
        &mut cs.hist,
    );
    let best = nlp::score_into(&cs.hist, wsum, &cs.pi, &mut cs.scores);
    ctx.publish(vid, best as u32);

    // 4. Migration (§IV-D.4): move to the sampled action when it beats
    // the current partition's score (the Spinner-candidate analogue —
    // Spinner also never migrates to a lower-score partition) and the
    // capacity gate admits it. Vertices sitting in an *over-capacity*
    // partition may leave unconditionally — draining b(l) > C back
    // under the eq. (1) bound takes precedence over locality.
    let action = cs.selected_of(v);
    let current = state.label(vid);
    if action != current
        && (cs.scores[action as usize] >= cs.scores[current as usize]
            || state.remaining(current as usize) < 0.0)
    {
        let p = ctx.demand.migration_probability(state, action as usize);
        if p > 0.0 && rng.next_f64() < p {
            state.migrate(vid, action, g.load_mass(vid));
            *migrations += 1;
        }
    }
    // Convergence signal S: the score of the vertex's (post-migration)
    // assignment — the same global objective Spinner's halting check
    // uses; the *best* score is a noisy constant on small graphs while
    // this tracks actual assignment quality.
    let current_score = cs.scores[state.label(vid) as usize] as f64;

    // 5. Raw weights (§IV-C step 4 + eq. 13): start from the normalized
    // LP scores ("scores generated from multiple passes of (10) are
    // evaluated by (13) to form the weight vector W") and add the
    // τ-normalized neighbour-preference modulation — neighbour u
    // endorses partition λ(u) with ŵ(u,v)/Σŵ when v's action agrees,
    // else with 1/Σŵ while λ(u) still has migration headroom.
    cs.raw_w.copy_from_slice(&cs.scores);
    let wsum_inv = if wsum > 1e-12 { 1.0 / wsum } else { 0.0 };
    for (&u, &w_uv) in g.neighbors(vid).iter().zip(g.neighbor_weights(vid)) {
        let lu = ctx.published(u) as usize;
        if lu == action as usize {
            cs.raw_w[lu] += w_uv * wsum_inv;
        } else if cs.headroom[lu] {
            cs.raw_w[lu] += wsum_inv;
        }
    }

    // 6+7. Signals + LA update (§IV-D.6/7).
    let rr = cs.row_range(v);
    if cfg.classic_la {
        // Ablation E5: classic single-action update (eqs. 6-7) — reward
        // the selected action iff it matches λ(v).
        let sig = if action as usize == best { Signal::Reward } else { Signal::Penalty };
        classic_update_row(&mut cs.probs[rr], action as usize, sig, cfg.alpha, cfg.beta);
    } else {
        build_signals_into(&cs.raw_w, &mut cs.w_norm, &mut cs.signals);
        // `probs` and the scratch vectors are distinct fields; split the
        // borrows explicitly.
        let ChunkState { probs, w_norm, signals, .. } = cs;
        WeightedLa::update(&mut probs[rr], w_norm, signals, cfg.alpha, cfg.beta);
    }

    current_score
}

/// Classic L_{R-P} row update (eqs. 6-7) used by the E5 ablation.
#[inline]
fn classic_update_row(row: &mut [f32], i: usize, sig: Signal, alpha: f32, beta: f32) {
    let m = row.len();
    match sig {
        Signal::Reward => {
            for j in 0..m {
                if j == i {
                    row[j] += alpha * (1.0 - row[j]);
                } else {
                    row[j] *= 1.0 - alpha;
                }
            }
        }
        Signal::Penalty => {
            let spread = beta / (m as f32 - 1.0);
            for j in 0..m {
                if j == i {
                    row[j] *= 1.0 - beta;
                } else {
                    row[j] = row[j] * (1.0 - beta) + spread;
                }
            }
        }
    }
}

/// XLA-engine phase-B body for one batch: scores through the `score`
/// artifact, migration host-side, LA updates through the `la_update`
/// artifact. Numerically equivalent to the native path (asserted in
/// integration tests).
fn xla_batch(
    ctx: &StepCtx<'_>,
    cs: &mut ChunkState,
    eng: &mut XlaStepEngine,
    range: Range<usize>,
    rng: &mut Rng,
    migrations: &mut u64,
) -> f64 {
    let k = cs.k;
    let len = range.len();
    debug_assert!(len <= BATCH);
    let g = ctx.graph;
    let state = ctx.state;

    // Gather histograms host-side (irregular CSR work stays on L3).
    let mut hist = vec![0.0f32; BATCH * k];
    let mut wsum = vec![0.0f32; BATCH];
    for (i, v) in range.clone().enumerate() {
        let vid = v as VertexId;
        wsum[i] = neighbor_histogram(
            g.neighbors(vid),
            g.neighbor_weights(vid),
            |u| ctx.label(u),
            &mut hist[i * k..(i + 1) * k],
        );
    }
    // Padded rows keep wsum=1 to avoid 0/0 in the kernel (scores unused).
    for w in wsum[len..].iter_mut() {
        *w = 1.0;
    }

    // L1 kernel: scores (B, k). The penalty term normalizes against the
    // system-level capacity (see PartitionState::system_capacity).
    let scores = eng
        .score(&hist, &wsum, &cs.loads, state.system_capacity() as f32)
        .expect("XLA score execution failed");

    let mut score_sum = 0.0f64;
    let mut raw_w = vec![0.0f32; BATCH * k];
    let mut probs = vec![0.0f32; BATCH * k];
    for (i, v) in range.clone().enumerate() {
        let vid = v as VertexId;
        let srow = &scores[i * k..(i + 1) * k];
        let mut best = 0usize;
        let mut best_s = f32::NEG_INFINITY;
        for (l, &s) in srow.iter().enumerate() {
            if s > best_s {
                best_s = s;
                best = l;
            }
        }
        ctx.publish(vid, best as u32);

        let action = cs.selected_of(v);
        let current = state.label(vid);
        if action != current
            && (srow[action as usize] >= srow[current as usize]
                || state.remaining(current as usize) < 0.0)
        {
            let p = ctx.demand.migration_probability(state, action as usize);
            if p > 0.0 && rng.next_f64() < p {
                state.migrate(vid, action, g.load_mass(vid));
                *migrations += 1;
            }
        }
        // Convergence signal: score of the post-migration assignment
        // (matches `native_vertex`).
        score_sum += srow[state.label(vid) as usize] as f64;

        // Raw weights (§IV-C step 4 + eq. 13), same semantics as
        // `native_vertex`.
        let wrow = &mut raw_w[i * k..(i + 1) * k];
        wrow.copy_from_slice(srow);
        let wsum_inv = if wsum[i] > 1e-12 { 1.0 / wsum[i] } else { 0.0 };
        for (&u, &w_uv) in g.neighbors(vid).iter().zip(g.neighbor_weights(vid)) {
            let lu = ctx.published(u) as usize;
            if lu == action as usize {
                wrow[lu] += w_uv * wsum_inv;
            } else if cs.headroom[lu] {
                wrow[lu] += wsum_inv;
            }
        }
        probs[i * k..(i + 1) * k].copy_from_slice(&cs.probs[cs.row_range(v)]);
    }
    // Pad rows beyond `len` with uniform distributions (the artifact has
    // a fixed batch dimension).
    for i in len..BATCH {
        WeightedLa::init(&mut probs[i * k..(i + 1) * k]);
    }

    // L1 kernel: signal construction + weighted LA update (B, k).
    let p_next = eng.la_update(&probs, &raw_w).expect("XLA la_update failed");
    for (i, v) in range.enumerate() {
        let rr = cs.row_range(v);
        cs.probs[rr].copy_from_slice(&p_next[i * k..(i + 1) * k]);
    }
    score_sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Schedule;
    use crate::graph::gen::{generate_dataset, Dataset};
    use crate::metrics::quality;

    fn small_cfg(k: usize) -> RevolverConfig {
        RevolverConfig {
            parts: k,
            max_steps: 60,
            threads: 2,
            seed: 11,
            ..Default::default()
        }
    }

    #[test]
    fn beats_hash_on_social_local_edges() {
        let g = generate_dataset(Dataset::Lj, 2048, 1).unwrap();
        let out = Revolver::new(small_cfg(4)).partition(&g);
        let le = quality::local_edges(&g, &out.labels);
        let hash_le = quality::local_edges(
            &g,
            &super::super::hash::HashPartitioner::new(4).partition(&g).labels,
        );
        assert!(le > hash_le + 0.1, "revolver={le} hash={hash_le}");
    }

    #[test]
    fn balanced_within_epsilon_margin() {
        // The paper's headline: max normalized load stays near 1+ε.
        let g = generate_dataset(Dataset::Lj, 2048, 2).unwrap();
        let out = Revolver::new(small_cfg(8)).partition(&g);
        let mnl = quality::max_normalized_load(&g, &out.labels, 8);
        assert!(mnl < 1.15, "mnl={mnl}");
    }

    #[test]
    fn labels_valid() {
        let g = generate_dataset(Dataset::So, 512, 3).unwrap();
        let out = Revolver::new(small_cfg(8)).partition(&g);
        assert_eq!(out.labels.len(), 512);
        assert!(out.labels.iter().all(|&l| l < 8));
    }

    #[test]
    fn deterministic_single_thread() {
        let g = generate_dataset(Dataset::Wiki, 512, 4).unwrap();
        let mut cfg = small_cfg(4);
        cfg.threads = 1;
        cfg.max_steps = 20;
        let a = Revolver::new(cfg.clone()).partition(&g);
        let b = Revolver::new(cfg).partition(&g);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn schedule_is_bitwise_irrelevant_at_one_thread() {
        // With a single worker both schedules degenerate to the same
        // 0..n chunk, so results must be bit-identical.
        let g = generate_dataset(Dataset::Lj, 512, 9).unwrap();
        let mut cfg = small_cfg(4);
        cfg.threads = 1;
        cfg.max_steps = 15;
        let vertex = Revolver::new(cfg.clone()).partition(&g);
        cfg.schedule = Schedule::Degree;
        let degree = Revolver::new(cfg).partition(&g);
        assert_eq!(vertex.labels, degree.labels);
    }

    #[test]
    fn degree_schedule_multithreaded_valid_and_balanced() {
        let g = generate_dataset(Dataset::Lj, 2048, 5).unwrap();
        let mut cfg = small_cfg(8);
        cfg.threads = 4;
        cfg.schedule = Schedule::Degree;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 8));
        let mnl = quality::max_normalized_load(&g, &out.labels, 8);
        assert!(mnl < 1.15, "mnl={mnl}");
    }

    #[test]
    fn sync_mode_runs() {
        let g = generate_dataset(Dataset::So, 512, 5).unwrap();
        let mut cfg = small_cfg(4);
        cfg.execution = ExecutionModel::Synchronous;
        cfg.max_steps = 20;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn classic_la_ablation_runs() {
        let g = generate_dataset(Dataset::So, 512, 6).unwrap();
        let mut cfg = small_cfg(4);
        cfg.classic_la = true;
        cfg.max_steps = 20;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn warm_row_is_normalized_and_biased() {
        for k in [2usize, 8, 32] {
            let mut row = vec![0.0f32; k];
            init_warm_row(&mut row, k / 2);
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "k={k} sum={sum}");
            let uniform = 1.0 / k as f32;
            assert!(row[k / 2] > uniform, "k={k}");
            for (i, &p) in row.iter().enumerate() {
                if i != k / 2 {
                    assert!(p > 0.0 && p < uniform, "k={k} i={i} p={p}");
                }
            }
        }
    }

    // The warm-vs-cold convergence assertion (stream:fennel init
    // reaches the halting threshold in <= the steps of random init)
    // lives in tests/integration.rs at acceptance scale.

    #[test]
    fn trace_enabled_records_improvement() {
        let g = generate_dataset(Dataset::Lj, 1024, 7).unwrap();
        let mut cfg = small_cfg(4);
        cfg.trace_every = 1;
        cfg.max_steps = 40;
        cfg.halt_window = 1000;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.trace.points.len() >= 30);
        let first = out.trace.points.first().unwrap().local_edges;
        let last = out.trace.points.last().unwrap().local_edges;
        assert!(last > first, "local edges should improve: {first} -> {last}");
    }
}
