//! Revolver — the paper's contribution (§IV): asynchronous vertex-centric
//! partitioning where each vertex's **weighted learning automaton** picks
//! its partition and is trained by the **normalized LP** objective.
//!
//! Step structure (§IV-D, Figure 2):
//!  1. every LA draws an action (candidate partition) — roulette wheel;
//!  2. candidates register migration *demand* m(l);
//!  3. normalized LP scores (eqs. 10–12) are computed per vertex and the
//!     argmax label λ(v) is published for neighbours;
//!  4. the vertex migrates to its selected action with probability
//!     min(1, r(l)/m(l)) when the action differs from its current label;
//!  5. raw weights are accumulated from neighbour λ's (eq. 13);
//!  6. the weight vector is mean-split into reward/penalty halves and
//!     half-normalized (§IV-D.6);
//!  7. the LA probability vector is updated (eqs. 8–9);
//!  8. convergence: halt after `halt_window` consecutive sub-θ steps.
//!
//! **Asynchronous** mode (the paper's headline implementation) reads
//! labels, loads and λ's live from shared atomics — workers see each
//! other's migrations mid-step ("progressively exchanged loads",
//! §V-H.2). **Synchronous** mode (ablation E4) freezes label/λ/load
//! snapshots per step, Giraph-style.
//!
//! Execution is delegated to [`crate::engine`]: steps 1–2 are the
//! engine's phase A, steps 3–7 its phase B, and λ(v) rides the engine's
//! per-vertex *published* channel (so the sync-mode freeze applies to it
//! automatically). This module only contains the per-vertex math; all
//! thread orchestration, snapshotting and halting live in the engine.
//!
//! Eq. (13) note: the printed equation mixes λ(v)/λ(u) and ψ indices
//! inconsistently; we implement the reading consistent with §IV-C step 4
//! ("scores … are evaluated by (13) to form the weight vector W"): the
//! raw weight vector starts from the vertex's own score vector, and each
//! neighbour u endorses partition λ(u) with ŵ(u,v)/Σŵ when v's selected
//! action agrees, else 1/Σŵ while λ(u) has migration headroom. DESIGN.md
//! §Fidelity-notes (F5–F7) records this and the other disambiguations.

use std::cell::UnsafeCell;

use super::{PartitionOutput, Partitioner};
use crate::config::{Engine, ExecutionModel, RevolverConfig};
use crate::engine::{self, StepCtx, StepStats, VertexProgram};
use crate::graph::Graph;
use crate::la::signal::build_signals_into;
use crate::la::weighted::WeightedLa;
use crate::la::{roulette, Signal};
use crate::lp::{clear_touched, neighbor_histogram, neighbor_histogram_sparse, normalized as nlp};
use crate::partition::{DemandTracker, InitialAssignment, PartitionState};
use crate::runtime::XlaStepEngine;
use crate::util::rng::Rng;
use crate::VertexId;

/// How many vertices share one load/π snapshot in the scoring loop (and
/// one XLA batch in `--engine xla`; must match the artifact batch dim).
pub const BATCH: usize = 256;

pub struct Revolver {
    cfg: RevolverConfig,
}

impl Revolver {
    pub fn new(cfg: RevolverConfig) -> Self {
        cfg.validate().expect("invalid config");
        Revolver { cfg }
    }

    /// Access the effective configuration.
    pub fn config(&self) -> &RevolverConfig {
        &self.cfg
    }
}

/// The LA probability rows (n × k floats), shared across all workers.
/// Rows are handed out mutably through `&self`; soundness rests on the
/// engine's scheduling contract ([`VertexProgram`] docs): a vertex
/// appears in exactly one worker's work list per superstep (chunk
/// cover-exactly + frontier dedup), so no two threads ever touch the
/// same row concurrently. The slab replaces the old per-chunk slabs —
/// under frontier-driven scheduling a worker's per-step work list is
/// not aligned with any static vertex range, so per-vertex persistent
/// state must be globally addressable.
struct ProbSlab {
    k: usize,
    cells: Vec<UnsafeCell<f32>>,
}

// SAFETY: concurrent access is only ever to disjoint rows (see above);
// `UnsafeCell` makes the aliasing explicit instead of lying with `&mut`.
unsafe impl Sync for ProbSlab {}

impl ProbSlab {
    fn new(n: usize, k: usize, warm: Option<&[crate::Label]>) -> Self {
        let mut flat = vec![0.0f32; n * k];
        match warm {
            None => {
                for row in flat.chunks_mut(k) {
                    WeightedLa::init(row);
                }
            }
            Some(labels) => {
                for (v, row) in flat.chunks_mut(k).enumerate() {
                    init_warm_row(row, labels[v] as usize);
                }
            }
        }
        ProbSlab { k, cells: flat.into_iter().map(UnsafeCell::new).collect() }
    }

    /// Vertex `v`'s probability row.
    ///
    /// SAFETY: the caller must be the only thread evaluating `v` in the
    /// current phase — guaranteed by the engine's disjoint work lists.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    unsafe fn row(&self, v: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(
            self.cells.as_ptr().add(v * self.k) as *mut f32,
            self.k,
        )
    }
}

/// Per-worker mutable scratch: the k-sized scoring buffers plus the
/// positional phase-A → phase-B hand-off, so the hot loop never
/// allocates.
struct ChunkState {
    /// The action each LA of this worker's *current work list* selected
    /// this step — positional (index `i` ↔ `work[i]`), relying on the
    /// engine's guarantee that both phases see the identical list.
    selected: Vec<u32>,
    k: usize,
    // Scratch (k-sized).
    /// All-zero between vertices; the sparse accumulation records which
    /// entries it dirtied in `touched` and clears only those (O(deg)
    /// instead of an O(k) fill per vertex — wins when k ≫ avg degree).
    hist: Vec<f32>,
    touched: Vec<u32>,
    scores: Vec<f32>,
    pi: Vec<f32>,
    raw_w: Vec<f32>,
    w_norm: Vec<f32>,
    signals: Vec<Signal>,
    loads: Vec<f32>,
    /// Per-batch precomputed "partition still has migration headroom"
    /// flags — replaces two atomic loads per neighbour in the eq.-(13)
    /// accumulation (perf log P3).
    headroom: Vec<bool>,
}

/// Warm-start mass on the streamed label: the row starts at
/// `1/k + WARM_BIAS·(1 − 1/k)` there — i.e. halfway between uniform
/// and deterministic — and the remainder spreads evenly, so the LA
/// keeps exploring but no longer burns steps rediscovering the
/// streaming pass's structure.
const WARM_BIAS: f32 = 0.5;

/// Initialize one LA probability row biased toward `warm`.
/// `hot = 0.5·(k+1)/k`, `cold = 0.5/k`; `hot + (k−1)·cold = 1`.
fn init_warm_row(row: &mut [f32], warm: usize) {
    let k = row.len() as f32;
    let hot = 1.0 / k + WARM_BIAS * (1.0 - 1.0 / k);
    let cold = (1.0 - hot) / (k - 1.0);
    row.fill(cold);
    row[warm] = hot;
}

impl ChunkState {
    fn new(k: usize) -> Self {
        ChunkState {
            selected: Vec::new(),
            k,
            hist: vec![0.0; k],
            touched: Vec::with_capacity(k),
            scores: vec![0.0; k],
            pi: vec![0.0; k],
            raw_w: vec![0.0; k],
            w_norm: vec![0.0; k],
            signals: vec![Signal::Penalty; k],
            loads: vec![0.0; k],
            headroom: vec![true; k],
        }
    }
}

/// Revolver as a [`VertexProgram`]: phase A draws actions and registers
/// demand, phase B scores/migrates/learns (natively or through the XLA
/// artifacts). The persistent per-vertex LA state lives in the program
/// itself ([`ProbSlab`]); scratch holds only ephemeral buffers.
struct RevolverProgram<'a> {
    cfg: &'a RevolverConfig,
    /// n × k LA probability rows — built uniform, or biased toward the
    /// warm-start labels (`--init stream:<algo>` / multilevel `refine`).
    probs: ProbSlab,
}

impl VertexProgram for RevolverProgram<'_> {
    type Scratch = (ChunkState, Option<XlaStepEngine>);
    type PhaseA = ();
    type PhaseB = ();

    fn execution(&self) -> ExecutionModel {
        self.cfg.execution
    }

    fn rng_salt(&self) -> u64 {
        0x5245564F // "REVO"
    }

    fn init_published(&self, v: VertexId, state: &PartitionState) -> u32 {
        // λ(v) starts at the initial label.
        state.label(v)
    }

    fn make_scratch(&self) -> Self::Scratch {
        // PJRT handles are !Send: construct inside the worker.
        let eng = match self.cfg.engine {
            Engine::Xla => Some(
                XlaStepEngine::load(
                    &self.cfg.artifacts_dir,
                    BATCH,
                    self.cfg.parts,
                    self.cfg.alpha,
                    self.cfg.beta,
                )
                .expect("failed to load XLA artifacts (run `make artifacts`)"),
            ),
            Engine::Native => None,
        };
        (ChunkState::new(self.cfg.parts), eng)
    }

    fn prepare_phase_a(&self, _g: &Graph, _state: &PartitionState, _step: u32) {}

    fn prepare_phase_b(
        &self,
        _g: &Graph,
        _state: &PartitionState,
        _demand: &DemandTracker,
        _step: u32,
    ) {
    }

    fn phase_a(
        &self,
        ctx: &StepCtx<'_>,
        _frozen: &(),
        scratch: &mut Self::Scratch,
        work: &[VertexId],
        rng: &mut Rng,
    ) -> StepStats {
        let cs = &mut scratch.0;
        // ── Action selection + demand (§IV-D.1/2) ──
        cs.selected.clear();
        for &v in work {
            // Frontier fast path, mirroring phase B's: an isolated
            // vertex is inert under active-set execution, so don't draw
            // an action or register demand it will never consume (dead
            // demand would deflate min(1, r(l)/m(l)) for real movers).
            // The positional slot still needs an entry; the current
            // label is the harmless "stay" action.
            if ctx.frontier_on() && ctx.graph.neighbors(v).is_empty() {
                cs.selected.push(ctx.state.label(v));
                continue;
            }
            // SAFETY: `v` is in this worker's work list only (engine
            // contract), so the row access is exclusive.
            let row: &[f32] = unsafe { self.probs.row(v as usize) };
            let a = roulette::spin(row, rng) as u32;
            cs.selected.push(a);
            if a != ctx.state.label(v) {
                ctx.demand.add(a as usize, ctx.graph.load_mass(v));
            }
        }
        StepStats::default()
    }

    fn phase_b(
        &self,
        ctx: &StepCtx<'_>,
        _frozen: &(),
        scratch: &mut Self::Scratch,
        work: &[VertexId],
        rng: &mut Rng,
    ) -> StepStats {
        let (cs, eng) = scratch;
        let k = cs.k;
        let mut stats = StepStats::default();
        let mut pos = 0usize; // position into `work` / `cs.selected`
        for batch in work.chunks(BATCH) {
            // One load/π snapshot per batch (async staleness tolerance;
            // exactly the artifact's granularity).
            ctx.state.loads_into(&mut cs.loads);
            nlp::penalty_into(&cs.loads, ctx.state.system_capacity() as f32, &mut cs.pi);
            let cap = ctx.state.capacity() as f32;
            for l in 0..k {
                cs.headroom[l] = ctx.demand.get(l) <= 0 || cs.loads[l] < cap;
            }
            match eng.as_mut() {
                Some(eng) => {
                    stats.score_sum += xla_batch(
                        ctx,
                        cs,
                        &self.probs,
                        eng,
                        batch,
                        pos,
                        rng,
                        &mut stats.migrations,
                    );
                }
                None => {
                    for (i, &v) in batch.iter().enumerate() {
                        let action = cs.selected[pos + i];
                        stats.score_sum += native_vertex(
                            ctx,
                            cs,
                            &self.probs,
                            v,
                            action,
                            rng,
                            &mut stats.migrations,
                            self.cfg,
                        );
                    }
                }
            }
            pos += batch.len();
        }
        stats
    }
}

impl Partitioner for Revolver {
    fn name(&self) -> &'static str {
        "revolver"
    }

    fn partition(&self, g: &Graph) -> PartitionOutput {
        // Probe the XLA engine on the main thread first: a worker panic
        // behind the barrier protocol would deadlock the coordinator, so
        // surface configuration errors (missing artifacts, wrong k,
        // mismatched alpha/beta) eagerly and cleanly here.
        if self.cfg.engine == Engine::Xla {
            XlaStepEngine::load(
                &self.cfg.artifacts_dir,
                BATCH,
                self.cfg.parts,
                self.cfg.alpha,
                self.cfg.beta,
            )
            .expect("failed to load XLA artifacts (run `make artifacts`)");
        }
        // Compute the initial assignment once: the engine seeds the
        // shared labels from it, and (for a streaming warm start) the
        // program biases each LA row toward its vertex's label.
        let init = engine::initial_assignment(g, &self.cfg);
        let warm = match &init {
            InitialAssignment::Given(labels) => Some(labels.clone()),
            _ => None,
        };
        let program = RevolverProgram {
            cfg: &self.cfg,
            probs: ProbSlab::new(g.num_vertices(), self.cfg.parts, warm.as_deref()),
        };
        engine::run_with_init(g, &self.cfg, &program, init)
    }
}

/// Run a bounded Revolver pass from an explicit initial assignment —
/// the multilevel V-cycle's per-level refiner. Every LA row starts
/// biased toward its vertex's given label (the same warm start the
/// streaming bridge uses), and on graphs with vertex weights the
/// demand/migration mass is the coarse vertex weight
/// ([`Graph::load_mass`]).
pub fn refine(g: &Graph, cfg: &RevolverConfig, init: Vec<crate::Label>) -> PartitionOutput {
    let program = RevolverProgram {
        cfg,
        probs: ProbSlab::new(g.num_vertices(), cfg.parts, Some(&init)),
    };
    engine::run_with_init(g, cfg, &program, InitialAssignment::Given(init))
}

/// [`refine`] with an explicit step-0 frontier: only `seeds` (plus
/// whatever their evaluation wakes) are re-evaluated, and every LA row
/// still starts biased toward its given label — the incremental repair
/// pass of [`crate::dynamic`].
pub fn refine_seeded(
    g: &Graph,
    cfg: &RevolverConfig,
    init: Vec<crate::Label>,
    seeds: Vec<crate::VertexId>,
) -> PartitionOutput {
    let program = RevolverProgram {
        cfg,
        probs: ProbSlab::new(g.num_vertices(), cfg.parts, Some(&init)),
    };
    engine::run_with_frontier(
        g,
        cfg,
        &program,
        InitialAssignment::Given(init),
        engine::InitialFrontier::Seeds(seeds),
    )
}

/// Native per-vertex phase-B body. Returns the vertex's score
/// contribution to the convergence signal S.
#[inline]
#[allow(clippy::too_many_arguments)]
fn native_vertex(
    ctx: &StepCtx<'_>,
    cs: &mut ChunkState,
    probs: &ProbSlab,
    vid: VertexId,
    action: u32,
    rng: &mut Rng,
    migrations: &mut u64,
    cfg: &RevolverConfig,
) -> f64 {
    let g = ctx.graph;
    let state = ctx.state;

    // Frontier fast path: an isolated vertex has no neighbourhood term,
    // so its score is pure penalty — evaluating it would chase the
    // globally emptiest partition forever (label churn with zero load
    // mass and nobody to wake). Under active-set execution it is
    // settled by construction: no migration, no λ change, no wakes —
    // it leaves the frontier after step 0. Legacy mode (`frontier=off`)
    // keeps the paper-faithful evaluation bit-exactly.
    if ctx.frontier_on() && g.neighbors(vid).is_empty() {
        return 0.0;
    }

    // 3. Normalized LP scores + λ(v) (eqs. 10-12). The histogram is
    // accumulated sparsely: `cs.hist` is all-zero between vertices and
    // only the entries this vertex touched are cleared afterwards.
    let wsum = neighbor_histogram_sparse(
        g.neighbors(vid),
        g.neighbor_weights(vid),
        |u| ctx.label(u),
        &mut cs.hist,
        &mut cs.touched,
    );
    let best = nlp::score_into(&cs.hist, wsum, &cs.pi, &mut cs.scores);
    clear_touched(&mut cs.hist, &mut cs.touched);
    ctx.publish(vid, best as u32);

    // 4. Migration (§IV-D.4): move to the sampled action when it beats
    // the current partition's score (the Spinner-candidate analogue —
    // Spinner also never migrates to a lower-score partition) and the
    // capacity gate admits it. Vertices sitting in an *over-capacity*
    // partition may leave unconditionally — draining b(l) > C back
    // under the eq. (1) bound takes precedence over locality.
    let current = state.label(vid);
    if action != current
        && (cs.scores[action as usize] >= cs.scores[current as usize]
            || state.remaining(current as usize) < 0.0)
    {
        let p = ctx.demand.migration_probability(state, action as usize);
        if p > 0.0 && rng.next_f64() < p {
            ctx.migrate(vid, action, g.load_mass(vid));
            *migrations += 1;
        }
    }
    // Convergence signal S: the score of the vertex's (post-migration)
    // assignment — the same global objective Spinner's halting check
    // uses; the *best* score is a noisy constant on small graphs while
    // this tracks actual assignment quality.
    let current_score = cs.scores[state.label(vid) as usize] as f64;

    // 5. Raw weights (§IV-C step 4 + eq. 13): start from the normalized
    // LP scores ("scores generated from multiple passes of (10) are
    // evaluated by (13) to form the weight vector W") and add the
    // τ-normalized neighbour-preference modulation — neighbour u
    // endorses partition λ(u) with ŵ(u,v)/Σŵ when v's action agrees,
    // else with 1/Σŵ while λ(u) still has migration headroom.
    // (`raw_w` stays a dense k-copy: it is seeded from the dense score
    // vector, not zero-filled, so there is nothing sparse to skip.)
    cs.raw_w.copy_from_slice(&cs.scores);
    let wsum_inv = if wsum > 1e-12 { 1.0 / wsum } else { 0.0 };
    for (&u, &w_uv) in g.neighbors(vid).iter().zip(g.neighbor_weights(vid)) {
        let lu = ctx.published(u) as usize;
        if lu == action as usize {
            cs.raw_w[lu] += w_uv * wsum_inv;
        } else if cs.headroom[lu] {
            cs.raw_w[lu] += wsum_inv;
        }
    }

    // 6+7. Signals + LA update (§IV-D.6/7).
    // SAFETY: exclusive row access per the engine's disjoint work lists.
    let row = unsafe { probs.row(vid as usize) };
    if cfg.classic_la {
        // Ablation E5: classic single-action update (eqs. 6-7) — reward
        // the selected action iff it matches λ(v).
        let sig = if action as usize == best { Signal::Reward } else { Signal::Penalty };
        classic_update_row(row, action as usize, sig, cfg.alpha, cfg.beta);
    } else {
        build_signals_into(&cs.raw_w, &mut cs.w_norm, &mut cs.signals);
        WeightedLa::update(row, &cs.w_norm, &cs.signals, cfg.alpha, cfg.beta);
    }

    // Keep the vertex in the frontier while it is unsettled: off its
    // argmax (a denied or unattempted improving move must retry — the
    // demand gate and loads it lost to are global state), or sitting in
    // an over-capacity partition (the unconditional eq.-(1) drain above
    // must keep retrying until b(l) ≤ C, even when label == argmax).
    let post = state.label(vid);
    if post != best as u32 || state.remaining(post as usize) < 0.0 {
        ctx.wake(vid);
    }

    current_score
}

/// Classic L_{R-P} row update (eqs. 6-7) used by the E5 ablation.
#[inline]
fn classic_update_row(row: &mut [f32], i: usize, sig: Signal, alpha: f32, beta: f32) {
    let m = row.len();
    match sig {
        Signal::Reward => {
            for j in 0..m {
                if j == i {
                    row[j] += alpha * (1.0 - row[j]);
                } else {
                    row[j] *= 1.0 - alpha;
                }
            }
        }
        Signal::Penalty => {
            let spread = beta / (m as f32 - 1.0);
            for j in 0..m {
                if j == i {
                    row[j] *= 1.0 - beta;
                } else {
                    row[j] = row[j] * (1.0 - beta) + spread;
                }
            }
        }
    }
}

/// XLA-engine phase-B body for one batch of the work list (`batch[i]`'s
/// selected action is `cs.selected[pos + i]`): scores through the
/// `score` artifact, migration host-side, LA updates through the
/// `la_update` artifact. Numerically equivalent to the native path
/// (asserted in integration tests), including the frontier-mode
/// isolated-vertex skip.
#[allow(clippy::too_many_arguments)]
fn xla_batch(
    ctx: &StepCtx<'_>,
    cs: &mut ChunkState,
    slab: &ProbSlab,
    eng: &mut XlaStepEngine,
    batch: &[VertexId],
    pos: usize,
    rng: &mut Rng,
    migrations: &mut u64,
) -> f64 {
    let k = cs.k;
    let len = batch.len();
    debug_assert!(len <= BATCH);
    let g = ctx.graph;
    let state = ctx.state;
    let skip = |vid: VertexId| ctx.frontier_on() && g.neighbors(vid).is_empty();

    // Gather histograms host-side (irregular CSR work stays on L3).
    let mut hist = vec![0.0f32; BATCH * k];
    let mut wsum = vec![0.0f32; BATCH];
    for (i, &vid) in batch.iter().enumerate() {
        wsum[i] = neighbor_histogram(
            g.neighbors(vid),
            g.neighbor_weights(vid),
            |u| ctx.label(u),
            &mut hist[i * k..(i + 1) * k],
        );
    }
    // Padded rows keep wsum=1 to avoid 0/0 in the kernel (scores unused).
    for w in wsum[len..].iter_mut() {
        *w = 1.0;
    }

    // L1 kernel: scores (B, k). The penalty term normalizes against the
    // system-level capacity (see PartitionState::system_capacity).
    let scores = eng
        .score(&hist, &wsum, &cs.loads, state.system_capacity() as f32)
        .expect("XLA score execution failed");

    let mut score_sum = 0.0f64;
    let mut raw_w = vec![0.0f32; BATCH * k];
    let mut probs = vec![0.0f32; BATCH * k];
    for (i, &vid) in batch.iter().enumerate() {
        let srow = &scores[i * k..(i + 1) * k];
        // Raw-weight and probability rows must exist for the fixed-shape
        // kernel even when the vertex is skipped (its update is simply
        // never copied back).
        let wrow = &mut raw_w[i * k..(i + 1) * k];
        wrow.copy_from_slice(srow);
        // SAFETY: exclusive row access per the engine's disjoint work
        // lists.
        probs[i * k..(i + 1) * k].copy_from_slice(unsafe { slab.row(vid as usize) });
        if skip(vid) {
            // Same semantics as `native_vertex`'s frontier fast path:
            // no publish, no migration, no LA update, score 0, no wake.
            continue;
        }
        let mut best = 0usize;
        let mut best_s = f32::NEG_INFINITY;
        for (l, &s) in srow.iter().enumerate() {
            if s > best_s {
                best_s = s;
                best = l;
            }
        }
        ctx.publish(vid, best as u32);

        let action = cs.selected[pos + i];
        let current = state.label(vid);
        if action != current
            && (srow[action as usize] >= srow[current as usize]
                || state.remaining(current as usize) < 0.0)
        {
            let p = ctx.demand.migration_probability(state, action as usize);
            if p > 0.0 && rng.next_f64() < p {
                ctx.migrate(vid, action, g.load_mass(vid));
                *migrations += 1;
            }
        }
        // Convergence signal: score of the post-migration assignment
        // (matches `native_vertex`).
        score_sum += srow[state.label(vid) as usize] as f64;

        // Raw weights (§IV-C step 4 + eq. 13), same semantics as
        // `native_vertex`.
        let wsum_inv = if wsum[i] > 1e-12 { 1.0 / wsum[i] } else { 0.0 };
        for (&u, &w_uv) in g.neighbors(vid).iter().zip(g.neighbor_weights(vid)) {
            let lu = ctx.published(u) as usize;
            if lu == action as usize {
                wrow[lu] += w_uv * wsum_inv;
            } else if cs.headroom[lu] {
                wrow[lu] += wsum_inv;
            }
        }
        // Unsettled self-wake (off-argmax or over-capacity drain
        // pending), matching `native_vertex`.
        let post = state.label(vid);
        if post != best as u32 || state.remaining(post as usize) < 0.0 {
            ctx.wake(vid);
        }
    }
    // Pad rows beyond `len` with uniform distributions (the artifact has
    // a fixed batch dimension).
    for i in len..BATCH {
        WeightedLa::init(&mut probs[i * k..(i + 1) * k]);
    }

    // L1 kernel: signal construction + weighted LA update (B, k).
    let p_next = eng.la_update(&probs, &raw_w).expect("XLA la_update failed");
    for (i, &vid) in batch.iter().enumerate() {
        if skip(vid) {
            continue; // frontier-settled: LA row stays frozen
        }
        // SAFETY: exclusive row access (see above).
        unsafe { slab.row(vid as usize) }.copy_from_slice(&p_next[i * k..(i + 1) * k]);
    }
    score_sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Schedule;
    use crate::graph::gen::{generate_dataset, Dataset};
    use crate::metrics::quality;

    fn small_cfg(k: usize) -> RevolverConfig {
        RevolverConfig {
            parts: k,
            max_steps: 60,
            threads: 2,
            seed: 11,
            ..Default::default()
        }
    }

    #[test]
    fn beats_hash_on_social_local_edges() {
        let g = generate_dataset(Dataset::Lj, 2048, 1).unwrap();
        let out = Revolver::new(small_cfg(4)).partition(&g);
        let le = quality::local_edges(&g, &out.labels);
        let hash_le = quality::local_edges(
            &g,
            &super::super::hash::HashPartitioner::new(4).partition(&g).labels,
        );
        assert!(le > hash_le + 0.1, "revolver={le} hash={hash_le}");
    }

    #[test]
    fn balanced_within_epsilon_margin() {
        // The paper's headline: max normalized load stays near 1+ε.
        let g = generate_dataset(Dataset::Lj, 2048, 2).unwrap();
        let out = Revolver::new(small_cfg(8)).partition(&g);
        let mnl = quality::max_normalized_load(&g, &out.labels, 8);
        assert!(mnl < 1.15, "mnl={mnl}");
    }

    #[test]
    fn labels_valid() {
        let g = generate_dataset(Dataset::So, 512, 3).unwrap();
        let out = Revolver::new(small_cfg(8)).partition(&g);
        assert_eq!(out.labels.len(), 512);
        assert!(out.labels.iter().all(|&l| l < 8));
    }

    #[test]
    fn deterministic_single_thread() {
        let g = generate_dataset(Dataset::Wiki, 512, 4).unwrap();
        let mut cfg = small_cfg(4);
        cfg.threads = 1;
        cfg.max_steps = 20;
        let a = Revolver::new(cfg.clone()).partition(&g);
        let b = Revolver::new(cfg).partition(&g);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn schedule_is_bitwise_irrelevant_at_one_thread() {
        // With a single worker both schedules degenerate to the same
        // 0..n chunk, so results must be bit-identical.
        let g = generate_dataset(Dataset::Lj, 512, 9).unwrap();
        let mut cfg = small_cfg(4);
        cfg.threads = 1;
        cfg.max_steps = 15;
        let vertex = Revolver::new(cfg.clone()).partition(&g);
        cfg.schedule = Schedule::Degree;
        let degree = Revolver::new(cfg).partition(&g);
        assert_eq!(vertex.labels, degree.labels);
    }

    #[test]
    fn degree_schedule_multithreaded_valid_and_balanced() {
        let g = generate_dataset(Dataset::Lj, 2048, 5).unwrap();
        let mut cfg = small_cfg(8);
        cfg.threads = 4;
        cfg.schedule = Schedule::Degree;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 8));
        let mnl = quality::max_normalized_load(&g, &out.labels, 8);
        assert!(mnl < 1.15, "mnl={mnl}");
    }

    #[test]
    fn frontier_skips_evaluations_at_fixed_budget() {
        use crate::config::Frontier;
        let g = generate_dataset(Dataset::Lj, 2048, 8).unwrap();
        let steps = 25u32;
        let mut cfg = small_cfg(4);
        cfg.threads = 1;
        cfg.max_steps = steps;
        cfg.halt_window = u32::MAX;
        cfg.frontier = Frontier::Off;
        let off = Revolver::new(cfg.clone()).partition(&g);
        assert_eq!(off.trace.total_evaluated, steps as u64 * 2048);
        cfg.frontier = Frontier::On;
        let on = Revolver::new(cfg).partition(&g);
        assert!(
            on.trace.total_evaluated < off.trace.total_evaluated,
            "on={} off={}",
            on.trace.total_evaluated,
            off.trace.total_evaluated
        );
        assert!(on.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn sync_mode_runs() {
        let g = generate_dataset(Dataset::So, 512, 5).unwrap();
        let mut cfg = small_cfg(4);
        cfg.execution = ExecutionModel::Synchronous;
        cfg.max_steps = 20;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn classic_la_ablation_runs() {
        let g = generate_dataset(Dataset::So, 512, 6).unwrap();
        let mut cfg = small_cfg(4);
        cfg.classic_la = true;
        cfg.max_steps = 20;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn warm_row_is_normalized_and_biased() {
        for k in [2usize, 8, 32] {
            let mut row = vec![0.0f32; k];
            init_warm_row(&mut row, k / 2);
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "k={k} sum={sum}");
            let uniform = 1.0 / k as f32;
            assert!(row[k / 2] > uniform, "k={k}");
            for (i, &p) in row.iter().enumerate() {
                if i != k / 2 {
                    assert!(p > 0.0 && p < uniform, "k={k} i={i} p={p}");
                }
            }
        }
    }

    // The warm-vs-cold convergence assertion (stream:fennel init
    // reaches the halting threshold in <= the steps of random init)
    // lives in tests/integration.rs at acceptance scale.

    #[test]
    fn trace_enabled_records_improvement() {
        let g = generate_dataset(Dataset::Lj, 1024, 7).unwrap();
        let mut cfg = small_cfg(4);
        cfg.trace_every = 1;
        cfg.max_steps = 40;
        cfg.halt_window = 1000;
        // Full sweeps: the point-count floor below assumes no
        // empty-frontier early halt.
        cfg.frontier = crate::config::Frontier::Off;
        let out = Revolver::new(cfg).partition(&g);
        assert!(out.trace.points.len() >= 30);
        let first = out.trace.points.first().unwrap().local_edges;
        let last = out.trace.points.last().unwrap().local_edges;
        assert!(last > first, "local edges should improve: {first} -> {last}");
    }
}
