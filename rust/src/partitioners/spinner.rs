//! Spinner (Martella et al., ICDE'17) — the synchronous LP baseline
//! (§III-A, eqs. 3–5), reimplemented faithfully: per-step frozen label
//! snapshots (BSP), candidate = argmax of the *unnormalized* score,
//! probabilistic migration gated on remaining capacity over demand.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

use super::{PartitionOutput, Partitioner};
use crate::config::RevolverConfig;
use crate::coordinator::{run_chunked, Chunks, ConvergenceDetector};
use crate::graph::Graph;
use crate::lp::{neighbor_histogram, spinner as sp};
use crate::metrics::quality;
use crate::metrics::trace::{RunTrace, TracePoint};
use crate::partition::{DemandTracker, InitialAssignment, PartitionState};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

/// Sentinel meaning "no migration wanted this step".
const STAY: u32 = u32::MAX;

pub struct Spinner {
    cfg: RevolverConfig,
}

impl Spinner {
    pub fn new(cfg: RevolverConfig) -> Self {
        cfg.validate().expect("invalid config");
        Spinner { cfg }
    }
}

impl Partitioner for Spinner {
    fn name(&self) -> &'static str {
        "spinner"
    }

    fn partition(&self, g: &Graph) -> PartitionOutput {
        let sw = Stopwatch::start();
        let cfg = &self.cfg;
        let k = cfg.parts;
        let n = g.num_vertices();
        let state = PartitionState::new(g, k, cfg.epsilon, InitialAssignment::Random(cfg.seed));
        let chunks = Chunks::new(n, cfg.threads);
        let base_rng = Rng::new(cfg.seed ^ 0x5350494E); // "SPIN"

        // Per-vertex candidate partition for this step (STAY = none).
        let candidates: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(STAY)).collect();
        let demand = DemandTracker::new(k);

        let mut detector = ConvergenceDetector::new(cfg.halt_theta, cfg.halt_window);
        let mut trace = RunTrace::default();
        let mut executed_steps: u32 = 0;

        // Per-chunk partial score sums (f64 bits in atomics; one writer
        // per slot).
        let score_parts: Vec<AtomicU64> = (0..chunks.len()).map(|_| AtomicU64::new(0)).collect();
        let migration_count = AtomicU64::new(0);

        for step in 0..cfg.max_steps {
            executed_steps = step + 1;
            demand.reset();
            // BSP: freeze the label snapshot and the load-derived
            // penalty for the whole step.
            let snapshot = state.labels_snapshot();
            let mut loads = vec![0.0f32; k];
            state.loads_into(&mut loads);
            let mut pi_hat = vec![0.0f32; k];
            sp::penalty_into(&loads, state.capacity() as f32, &mut pi_hat);

            // Phase 1: score every vertex against the snapshot; register
            // candidates and demand.
            run_chunked(&chunks, |c, range| {
                let mut hist = vec![0.0f32; k];
                let mut scores = vec![0.0f32; k];
                let mut score_sum = 0.0f64;
                for v in range {
                    let vid = v as u32;
                    let wsum = neighbor_histogram(
                        g.neighbors(vid),
                        g.neighbor_weights(vid),
                        |u| snapshot[u as usize],
                        &mut hist,
                    );
                    let best = sp::score_into(&hist, wsum, &pi_hat, &mut scores);
                    let current = snapshot[v] as usize;
                    score_sum += scores[current] as f64;
                    if best != current {
                        candidates[v].store(best as u32, Ordering::Relaxed);
                        demand.add(best, g.out_degree(vid));
                    } else {
                        candidates[v].store(STAY, Ordering::Relaxed);
                    }
                }
                score_parts[c].store(score_sum.to_bits(), Ordering::Relaxed);
            });

            // Migration probabilities frozen after the demand phase
            // (this is Spinner's synchronous model).
            let mig_prob: Vec<f64> =
                (0..k).map(|l| demand.migration_probability(&state, l)).collect();

            // Phase 2: probabilistic migrations.
            migration_count.store(0, Ordering::Relaxed);
            run_chunked(&chunks, |c, range| {
                let mut rng = base_rng.fork(step as u64 * chunks.len() as u64 + c as u64);
                let mut local_migrations = 0u64;
                for v in range {
                    let cand = candidates[v].load(Ordering::Relaxed);
                    if cand == STAY {
                        continue;
                    }
                    if rng.next_f64() < mig_prob[cand as usize] {
                        state.migrate(v as u32, cand, g.out_degree(v as u32));
                        local_migrations += 1;
                    }
                }
                migration_count.fetch_add(local_migrations, Ordering::Relaxed);
            });

            // Convergence bookkeeping.
            let mean_score = score_parts
                .iter()
                .map(|s| f64::from_bits(s.load(Ordering::Relaxed)))
                .sum::<f64>()
                / n as f64;
            let migrations = migration_count.load(Ordering::Relaxed);

            let trace_now = cfg.trace_every > 0 && step % cfg.trace_every == 0;
            if trace_now {
                let labels = state.labels_snapshot();
                trace.push(TracePoint {
                    step,
                    local_edges: quality::local_edges(g, &labels),
                    max_normalized_load: quality::max_normalized_load(g, &labels, k),
                    mean_score,
                    migrations,
                });
            }

            if detector.observe(mean_score) {
                trace.converged_at = Some(step);
                break;
            }
        }

        let labels = state.labels_snapshot();
        debug_assert!(state.check_load_invariant().is_ok());
        if trace.points.is_empty() || cfg.trace_every == 0 {
            let q = quality::evaluate(g, &labels, k);
            trace.push(TracePoint {
                step: executed_steps.max(1) - 1,
                local_edges: q.local_edges,
                max_normalized_load: q.max_normalized_load,
                mean_score: 0.0,
                migrations: 0,
            });
        }
        trace.wall_time_s = sw.elapsed_s();
        PartitionOutput { labels, trace }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_dataset, Dataset};

    fn small_cfg(k: usize) -> RevolverConfig {
        RevolverConfig {
            parts: k,
            max_steps: 60,
            threads: 2,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn improves_over_hash_on_social() {
        let g = generate_dataset(Dataset::Lj, 2048, 1).unwrap();
        let out = Spinner::new(small_cfg(4)).partition(&g);
        let le = quality::local_edges(&g, &out.labels);
        let hash_le = quality::local_edges(
            &g,
            &super::super::hash::HashPartitioner::new(4).partition(&g).labels,
        );
        assert!(le > hash_le + 0.1, "spinner={le} hash={hash_le}");
    }

    #[test]
    fn labels_in_range_and_invariant() {
        let g = generate_dataset(Dataset::So, 1024, 2).unwrap();
        let out = Spinner::new(small_cfg(8)).partition(&g);
        assert_eq!(out.labels.len(), 1024);
        assert!(out.labels.iter().all(|&l| l < 8));
    }

    #[test]
    fn deterministic_across_runs_single_thread() {
        let g = generate_dataset(Dataset::Wiki, 512, 3).unwrap();
        let mut cfg = small_cfg(4);
        cfg.threads = 1;
        let a = Spinner::new(cfg.clone()).partition(&g);
        let b = Spinner::new(cfg).partition(&g);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn trace_recorded_when_enabled() {
        let g = generate_dataset(Dataset::So, 512, 4).unwrap();
        let mut cfg = small_cfg(4);
        cfg.trace_every = 1;
        cfg.max_steps = 10;
        cfg.halt_window = 100; // don't halt early
        let out = Spinner::new(cfg).partition(&g);
        assert!(out.trace.points.len() >= 9, "{}", out.trace.points.len());
        // Steps monotone.
        for w in out.trace.points.windows(2) {
            assert!(w[0].step < w[1].step);
        }
    }

    #[test]
    fn respects_capacity_loosely() {
        // Spinner can overshoot epsilon (the paper's critique) but must
        // stay within sanity bounds on a balanced graph.
        let g = generate_dataset(Dataset::So, 2048, 5).unwrap();
        let out = Spinner::new(small_cfg(8)).partition(&g);
        let mnl = quality::max_normalized_load(&g, &out.labels, 8);
        assert!(mnl < 1.8, "mnl={mnl}");
    }
}
