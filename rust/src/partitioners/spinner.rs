//! Spinner (Martella et al., ICDE'17) — the synchronous LP baseline
//! (§III-A, eqs. 3–5), reimplemented faithfully: per-step frozen label
//! snapshots (BSP), candidate = argmax of the *unnormalized* score,
//! probabilistic migration gated on remaining capacity over demand.
//!
//! Runs on [`crate::engine`] as a [`VertexProgram`]: scoring/demand is
//! phase A, probabilistic migration is phase B, and Spinner's two frozen
//! per-step quantities map onto the engine's coordinator hooks — the
//! penalty vector π̂ is frozen before phase A, the migration
//! probabilities after the demand phase. The program always reports
//! [`ExecutionModel::Synchronous`], so the engine's label snapshots give
//! the BSP read semantics regardless of the configured execution model
//! (Spinner has no asynchronous variant in the paper).

use super::{PartitionOutput, Partitioner};
use crate::config::{ExecutionModel, RevolverConfig};
use crate::engine::{self, StepCtx, StepStats, VertexProgram};
use crate::graph::Graph;
use crate::lp::{neighbor_histogram, neighbor_histogram_counts, spinner as sp};
use crate::partition::{DemandTracker, PartitionState};
use crate::util::rng::Rng;
use crate::VertexId;

/// Sentinel meaning "no migration wanted this step".
const STAY: u32 = u32::MAX;

pub struct Spinner {
    cfg: RevolverConfig,
}

impl Spinner {
    pub fn new(cfg: RevolverConfig) -> Self {
        cfg.validate().expect("invalid config");
        Spinner { cfg }
    }
}

/// Per-worker scratch: k-sized scoring buffers plus the candidate
/// partitions of this worker's current work list (phase A → phase B
/// hand-off, positional — index `i` ↔ `work[i]`, relying on the
/// engine's guarantee that both phases see the identical list).
struct SpinnerScratch {
    hist: Vec<f32>,
    /// u32 twin of `hist` for the integer-weight fast path
    /// ([`neighbor_histogram_counts`]).
    hist_u32: Vec<u32>,
    scores: Vec<f32>,
    candidates: Vec<u32>,
}

struct SpinnerProgram<'a> {
    cfg: &'a RevolverConfig,
}

impl VertexProgram for SpinnerProgram<'_> {
    type Scratch = SpinnerScratch;
    /// π̂(l) = b(l)/C, frozen from the loads at step start (eq. 5).
    type PhaseA = Vec<f32>;
    /// Migration probabilities min(1, r(l)/m(l)), frozen after the
    /// demand phase — this is Spinner's synchronous model.
    type PhaseB = Vec<f64>;

    fn execution(&self) -> ExecutionModel {
        ExecutionModel::Synchronous
    }

    fn rng_salt(&self) -> u64 {
        0x5350494E // "SPIN"
    }

    fn init_published(&self, v: VertexId, state: &PartitionState) -> u32 {
        // Spinner never reads the published channel; keep it at the
        // label so the engine's snapshots stay meaningful.
        state.label(v)
    }

    fn make_scratch(&self) -> SpinnerScratch {
        let k = self.cfg.parts;
        SpinnerScratch {
            hist: vec![0.0; k],
            hist_u32: vec![0; k],
            scores: vec![0.0; k],
            candidates: Vec::new(),
        }
    }

    fn la_decisiveness(&self, _verts: &[VertexId]) -> Option<crate::obs::diag::Decisiveness> {
        // Label propagation keeps no per-vertex probability rows, so
        // there is nothing to measure — the diag event simply omits
        // the decisiveness means.
        None
    }

    fn prepare_phase_a(&self, _g: &Graph, state: &PartitionState, _step: u32) -> Vec<f32> {
        let t = crate::obs::enabled().then(crate::util::Stopwatch::start);
        let k = self.cfg.parts;
        let mut loads = vec![0.0f32; k];
        state.loads_into(&mut loads);
        let mut pi_hat = vec![0.0f32; k];
        sp::penalty_into(&loads, state.capacity() as f32, &mut pi_hat);
        if let Some(w) = t {
            // Histogram, not a span: prep runs as the coordinator's
            // barrier-crossing segments' siblings and a child span here
            // would double-count inside the engine's profile tree.
            crate::obs::observe("spinner_prep_a_us", (w.elapsed_s() * 1e6) as u64);
        }
        pi_hat
    }

    fn prepare_phase_b(
        &self,
        _g: &Graph,
        state: &PartitionState,
        demand: &DemandTracker,
        _step: u32,
    ) -> Vec<f64> {
        let t = crate::obs::enabled().then(crate::util::Stopwatch::start);
        let p = (0..self.cfg.parts).map(|l| demand.migration_probability(state, l)).collect();
        if let Some(w) = t {
            crate::obs::observe("spinner_prep_b_us", (w.elapsed_s() * 1e6) as u64);
        }
        p
    }

    fn phase_a(
        &self,
        ctx: &StepCtx<'_>,
        pi_hat: &Vec<f32>,
        s: &mut SpinnerScratch,
        work: &[VertexId],
        _rng: &mut Rng,
    ) -> StepStats {
        // Score every active vertex against the frozen snapshot;
        // register candidates and demand.
        let mut score_sum = 0.0f64;
        s.candidates.clear();
        for &vid in work {
            // Frontier fast path: an isolated vertex's score is pure
            // penalty, so it would chase the emptiest partition forever
            // while waking nobody — under active-set execution it is
            // settled by construction. Legacy mode keeps the original
            // evaluation.
            if ctx.frontier_on() && ctx.graph.neighbors(vid).is_empty() {
                s.candidates.push(STAY);
                continue;
            }
            // Integer-weight fast path (eq.-(4) graphs): u32 gather +
            // count scoring, bit-exact to the f32 path (lp tests).
            let best = if !ctx.graph.is_weighted() {
                let cnt = neighbor_histogram_counts(
                    ctx.graph.neighbors(vid),
                    ctx.graph.neighbor_weights(vid),
                    |u| ctx.label(u),
                    &mut s.hist_u32,
                );
                sp::score_counts_into(&s.hist_u32, cnt, pi_hat, &mut s.scores)
            } else {
                let wsum = neighbor_histogram(
                    ctx.graph.neighbors(vid),
                    ctx.graph.neighbor_weights(vid),
                    |u| ctx.label(u),
                    &mut s.hist,
                );
                sp::score_into(&s.hist, wsum, pi_hat, &mut s.scores)
            };
            let current = ctx.label(vid) as usize;
            score_sum += s.scores[current] as f64;
            s.candidates.push(if best != current {
                ctx.demand.add(best, ctx.graph.load_mass(vid));
                best as u32
            } else {
                STAY
            });
        }
        StepStats { score_sum, ..StepStats::default() }
    }

    fn phase_b(
        &self,
        ctx: &StepCtx<'_>,
        mig_prob: &Vec<f64>,
        s: &mut SpinnerScratch,
        work: &[VertexId],
        rng: &mut Rng,
    ) -> StepStats {
        // Probabilistic migrations against the frozen probabilities.
        let mut migrations = 0u64;
        for (i, &vid) in work.iter().enumerate() {
            let cand = s.candidates[i];
            if cand == STAY {
                continue;
            }
            if rng.next_f64() < mig_prob[cand as usize] {
                // Wakes the vertex and its neighbourhood (their frozen
                // snapshots change next step).
                ctx.migrate(vid, cand, ctx.graph.load_mass(vid));
                migrations += 1;
            } else {
                // The candidate stands but the coin (or the capacity
                // gate, via a zero probability) denied the move: stay in
                // the frontier and retry — demand and loads are global
                // state that can change without any neighbour event.
                ctx.wake(vid);
            }
        }
        StepStats { migrations, ..StepStats::default() }
    }
}

impl Partitioner for Spinner {
    fn name(&self) -> &'static str {
        "spinner"
    }

    fn try_partition(&self, g: &Graph) -> Result<PartitionOutput, engine::EngineError> {
        engine::run(g, &self.cfg, &SpinnerProgram { cfg: &self.cfg })
    }
}

/// Run a bounded Spinner pass from an explicit initial assignment —
/// the multilevel V-cycle's per-level refiner. Step budget and halting
/// come from `cfg` (`max_steps` is the bound); on graphs with vertex
/// weights the capacity gate works in coarse-vertex-weight units via
/// [`Graph::load_mass`].
pub fn refine(
    g: &Graph,
    cfg: &RevolverConfig,
    init: Vec<crate::Label>,
) -> Result<PartitionOutput, engine::EngineError> {
    engine::run_with_init(
        g,
        cfg,
        &SpinnerProgram { cfg },
        crate::partition::InitialAssignment::Given(init),
    )
}

/// [`refine`] with an explicit step-0 frontier: only `seeds` (plus
/// whatever their evaluation wakes) are re-evaluated — the incremental
/// repair pass of [`crate::dynamic`], where `seeds` are the endpoints
/// of an update batch and their undirected neighbourhoods.
pub fn refine_seeded(
    g: &Graph,
    cfg: &RevolverConfig,
    init: Vec<crate::Label>,
    seeds: Vec<crate::VertexId>,
) -> Result<PartitionOutput, engine::EngineError> {
    engine::run_with_frontier(
        g,
        cfg,
        &SpinnerProgram { cfg },
        crate::partition::InitialAssignment::Given(init),
        engine::InitialFrontier::Seeds(seeds),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_dataset, Dataset};
    use crate::metrics::quality;

    fn small_cfg(k: usize) -> RevolverConfig {
        RevolverConfig {
            parts: k,
            max_steps: 60,
            threads: 2,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn improves_over_hash_on_social() {
        let g = generate_dataset(Dataset::Lj, 2048, 1).unwrap();
        let out = Spinner::new(small_cfg(4)).partition(&g);
        let le = quality::local_edges(&g, &out.labels);
        let hash_le = quality::local_edges(
            &g,
            &super::super::hash::HashPartitioner::new(4).partition(&g).labels,
        );
        assert!(le > hash_le + 0.1, "spinner={le} hash={hash_le}");
    }

    #[test]
    fn labels_in_range_and_invariant() {
        let g = generate_dataset(Dataset::So, 1024, 2).unwrap();
        let out = Spinner::new(small_cfg(8)).partition(&g);
        assert_eq!(out.labels.len(), 1024);
        assert!(out.labels.iter().all(|&l| l < 8));
    }

    #[test]
    fn deterministic_across_runs_single_thread() {
        let g = generate_dataset(Dataset::Wiki, 512, 3).unwrap();
        let mut cfg = small_cfg(4);
        cfg.threads = 1;
        let a = Spinner::new(cfg.clone()).partition(&g);
        let b = Spinner::new(cfg).partition(&g);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn bsp_multithreaded_matches_single_thread_quality() {
        // Spinner is fully synchronous — phase A reads only frozen
        // snapshots and phase B flips coins against frozen
        // probabilities — so thread count changes only the per-chunk RNG
        // streams, never the dynamics. Quality must be stable across
        // thread counts (labels differ because the coin-flip streams are
        // chunk-indexed).
        let g = generate_dataset(Dataset::So, 1024, 4).unwrap();
        let mut cfg = small_cfg(4);
        cfg.threads = 1;
        let a = Spinner::new(cfg.clone()).partition(&g);
        cfg.threads = 4;
        let b = Spinner::new(cfg).partition(&g);
        let qa = quality::evaluate(&g, &a.labels, 4);
        let qb = quality::evaluate(&g, &b.labels, 4);
        assert!((qa.local_edges - qb.local_edges).abs() < 0.1, "{qa:?} vs {qb:?}");
    }

    #[test]
    fn trace_recorded_when_enabled() {
        let g = generate_dataset(Dataset::So, 512, 4).unwrap();
        let mut cfg = small_cfg(4);
        cfg.trace_every = 1;
        cfg.max_steps = 10;
        cfg.halt_window = 100; // don't halt early
        // Full sweeps: the point-count floor below assumes no
        // empty-frontier early halt.
        cfg.frontier = crate::config::Frontier::Off;
        let out = Spinner::new(cfg).partition(&g);
        assert!(out.trace.points.len() >= 9, "{}", out.trace.points.len());
        // Steps monotone.
        for w in out.trace.points.windows(2) {
            assert!(w[0].step < w[1].step);
        }
    }

    #[test]
    fn respects_capacity_loosely() {
        // Spinner can overshoot epsilon (the paper's critique) but must
        // stay within sanity bounds on a balanced graph.
        let g = generate_dataset(Dataset::So, 2048, 5).unwrap();
        let out = Spinner::new(small_cfg(8)).partition(&g);
        let mnl = quality::max_normalized_load(&g, &out.labels, 8);
        assert!(mnl < 1.8, "mnl={mnl}");
    }
}
