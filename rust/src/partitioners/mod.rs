//! The partitioning algorithms: the four the paper evaluates (§V-D) —
//! Revolver (this paper), Spinner (LP baseline), Hash, and Range —
//! plus the streaming family ([`crate::stream`]): LDG, Fennel, and
//! prioritized restreaming.

pub mod hash;
pub mod range;
pub mod revolver;
pub mod spinner;

use crate::graph::Graph;
use crate::metrics::trace::RunTrace;
use crate::Label;

/// Result of a partitioning run.
#[derive(Debug, Clone)]
pub struct PartitionOutput {
    /// Final label per vertex.
    pub labels: Vec<Label>,
    /// Per-step trace (empty for the one-shot Hash/Range partitioners).
    pub trace: RunTrace,
}

/// Common interface over all partitioners.
pub trait Partitioner {
    /// Short algorithm name used in reports ("revolver", "spinner", ...).
    fn name(&self) -> &'static str;

    /// Partition `g`; `k` and all other knobs come from the
    /// implementation's config.
    fn partition(&self, g: &Graph) -> PartitionOutput;
}

/// Construct a partitioner by report name — the CLI/bench entry point.
pub fn by_name(
    name: &str,
    cfg: crate::config::RevolverConfig,
) -> anyhow::Result<Box<dyn Partitioner>> {
    match name.to_lowercase().as_str() {
        "revolver" => Ok(Box::new(revolver::Revolver::new(cfg))),
        "spinner" => Ok(Box::new(spinner::Spinner::new(cfg))),
        "hash" => Ok(Box::new(hash::HashPartitioner::new(cfg.parts))),
        "range" => Ok(Box::new(range::RangePartitioner::new(cfg.parts))),
        "ldg" => Ok(Box::new(crate::stream::Ldg::new(cfg))),
        "fennel" => Ok(Box::new(crate::stream::Fennel::new(cfg))),
        "restream" => Ok(Box::new(crate::stream::Restream::new(cfg))),
        other => anyhow::bail!(
            "unknown partitioner {other:?} \
             (expected revolver|spinner|hash|range|ldg|fennel|restream)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RevolverConfig;

    #[test]
    fn by_name_constructs_all() {
        let cfg = RevolverConfig { parts: 4, ..Default::default() };
        for name in
            ["revolver", "spinner", "hash", "range", "ldg", "fennel", "restream", "HASH"]
        {
            let p = by_name(name, cfg.clone()).unwrap();
            assert!(!p.name().is_empty());
        }
        assert!(by_name("metis", cfg).is_err());
    }
}
