//! The partitioning algorithms: the four the paper evaluates (§V-D) —
//! Revolver (this paper), Spinner (LP baseline), Hash, and Range —
//! plus the streaming family ([`crate::stream`]): LDG, Fennel, and
//! prioritized restreaming — plus the multilevel V-cycle
//! ([`crate::multilevel`]) that drives Spinner/Revolver as per-level
//! refiners over a heavy-edge coarsening hierarchy.

pub mod hash;
pub mod range;
pub mod revolver;
pub mod spinner;

use crate::graph::Graph;
use crate::metrics::trace::RunTrace;
use crate::Label;

/// Result of a partitioning run.
#[derive(Debug, Clone)]
pub struct PartitionOutput {
    /// Final label per vertex.
    pub labels: Vec<Label>,
    /// Per-step trace (empty for the one-shot Hash/Range partitioners).
    pub trace: RunTrace,
}

/// Common interface over all partitioners.
pub trait Partitioner {
    /// Short algorithm name used in reports ("revolver", "spinner", ...).
    fn name(&self) -> &'static str;

    /// Partition `g`; `k` and all other knobs come from the
    /// implementation's config. A contained worker panic (see
    /// [`crate::engine::EngineError`]) is the only error: the one-shot
    /// and streaming partitioners are infallible and always `Ok`.
    fn try_partition(&self, g: &Graph) -> Result<PartitionOutput, crate::engine::EngineError>;

    /// [`Partitioner::try_partition`], panicking on a contained worker
    /// panic — the ergonomic entry point for benches, tests and callers
    /// that have no recovery story anyway. The CLI and the incremental
    /// partitioner use `try_partition` so an aborted run maps to a
    /// distinct exit code instead of a panic.
    fn partition(&self, g: &Graph) -> PartitionOutput {
        self.try_partition(g)
            .unwrap_or_else(|e| panic!("{}: {e}", self.name()))
    }
}

/// The multilevel V-cycle family: names that may never be used as a
/// multilevel `coarse_algo` (the coarsest level would recurse into
/// another V-cycle without bound). Config validation reads this; the
/// registry sync test asserts it stays a subset of [`REGISTRY`].
pub const MULTILEVEL_FAMILY: &[&str] = &["multilevel", "ml-spinner", "ml-revolver"];

/// Every name [`by_name`] accepts, in display order. Single source of
/// truth for the CLI usage text and the unknown-algorithm error; a test
/// below asserts it stays in sync with the construction match.
pub const REGISTRY: &[&str] = &[
    "revolver",
    "spinner",
    "hash",
    "range",
    "ldg",
    "fennel",
    "restream",
    "multilevel",
    "ml-spinner",
    "ml-revolver",
];

/// Construct a partitioner by report name — the CLI/bench entry point.
pub fn by_name(
    name: &str,
    cfg: crate::config::RevolverConfig,
) -> anyhow::Result<Box<dyn Partitioner>> {
    use crate::multilevel::{Multilevel, Refiner};
    match name.to_lowercase().as_str() {
        "revolver" => Ok(Box::new(revolver::Revolver::new(cfg))),
        "spinner" => Ok(Box::new(spinner::Spinner::new(cfg))),
        "hash" => Ok(Box::new(hash::HashPartitioner::new(cfg.parts))),
        "range" => Ok(Box::new(range::RangePartitioner::new(cfg.parts))),
        "ldg" => Ok(Box::new(crate::stream::Ldg::new(cfg))),
        "fennel" => Ok(Box::new(crate::stream::Fennel::new(cfg))),
        "restream" => Ok(Box::new(crate::stream::Restream::new(cfg))),
        // The V-cycle's default refiner is Spinner (LP benefits most
        // from a near-good seed, Spinner's ICDE'17 observation).
        "multilevel" | "ml-spinner" => Ok(Box::new(Multilevel::new(cfg))),
        "ml-revolver" => Ok(Box::new(Multilevel::with_refiner(cfg, Refiner::Revolver))),
        other => anyhow::bail!(
            "unknown partitioner {other:?} (expected one of: {})",
            REGISTRY.join("|")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RevolverConfig;

    #[test]
    fn by_name_constructs_all() {
        let cfg = RevolverConfig { parts: 4, ..Default::default() };
        for name in
            ["revolver", "spinner", "hash", "range", "ldg", "fennel", "restream", "HASH"]
        {
            let p = by_name(name, cfg.clone()).unwrap();
            assert!(!p.name().is_empty());
        }
        assert!(by_name("metis", cfg).is_err());
    }

    #[test]
    fn registry_stays_in_sync_with_by_name() {
        let cfg = RevolverConfig { parts: 4, ..Default::default() };
        // Every registered name constructs (the match accepts it)…
        for name in REGISTRY {
            let p = by_name(name, cfg.clone())
                .unwrap_or_else(|e| panic!("registered {name:?} must construct: {e}"));
            assert!(!p.name().is_empty());
        }
        // …and the unknown-name error enumerates every registered name.
        // (The reverse direction — a match arm missing from REGISTRY —
        // is not mechanically checkable here; REGISTRY is the single
        // source the error text, usage string and coarse_algo validation
        // all read, so an unlisted arm is unreachable from those paths.)
        let err = by_name("metis", cfg).unwrap_err().to_string();
        for name in REGISTRY {
            assert!(err.contains(name), "error must list {name:?}: {err}");
        }
    }

    #[test]
    fn multilevel_family_guard_covers_registry() {
        // Every family name is registered, and the recursion guard in
        // config validation rejects each one as a coarse_algo.
        for name in MULTILEVEL_FAMILY {
            assert!(REGISTRY.contains(name), "{name:?} must be in REGISTRY");
            let cfg = RevolverConfig {
                parts: 4,
                coarse_algo: name.to_string(),
                ..Default::default()
            };
            assert!(cfg.validate().is_err(), "{name:?} must be rejected as coarse_algo");
        }
    }
}
