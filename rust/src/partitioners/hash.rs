//! Hash partitioning (§V-D): vertex `v` goes to partition `v mod k`.
//!
//! The classic "no information" baseline: perfectly balanced in vertex
//! count (and near-balanced in edges for skew-free graphs), but places
//! neighbours apart on purpose-free grounds, so local edges ≈ 1/k.

use super::{PartitionOutput, Partitioner};
use crate::graph::Graph;
use crate::metrics::trace::RunTrace;

pub struct HashPartitioner {
    k: usize,
}

impl HashPartitioner {
    pub fn new(k: usize) -> Self {
        assert!(k >= 2);
        HashPartitioner { k }
    }
}

impl Partitioner for HashPartitioner {
    fn name(&self) -> &'static str {
        "hash"
    }

    fn try_partition(&self, g: &Graph) -> Result<PartitionOutput, crate::engine::EngineError> {
        let labels = (0..g.num_vertices()).map(|v| (v % self.k) as u32).collect();
        Ok(PartitionOutput { labels, trace: RunTrace::default() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_dataset, Dataset};
    use crate::metrics::quality;

    #[test]
    fn labels_are_v_mod_k() {
        let g = generate_dataset(Dataset::So, 256, 1).unwrap();
        let out = HashPartitioner::new(4).partition(&g);
        for (v, &l) in out.labels.iter().enumerate() {
            assert_eq!(l, (v % 4) as u32);
        }
    }

    #[test]
    fn local_edges_near_one_over_k() {
        // On an ER graph, hash local edges ≈ 1/k.
        let g = generate_dataset(Dataset::So, 2048, 2).unwrap();
        let k = 8;
        let out = HashPartitioner::new(k).partition(&g);
        let le = quality::local_edges(&g, &out.labels);
        assert!((le - 1.0 / k as f64).abs() < 0.02, "le={le}");
    }

    #[test]
    fn balanced_on_skew_free() {
        let g = generate_dataset(Dataset::So, 2048, 3).unwrap();
        let out = HashPartitioner::new(8).partition(&g);
        let mnl = quality::max_normalized_load(&g, &out.labels, 8);
        assert!(mnl < 1.1, "mnl={mnl}");
    }
}
