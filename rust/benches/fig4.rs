//! E3 — Figure 4 reproduction: per-step convergence of local edges and
//! max normalized load, Revolver vs Spinner on the LJ surrogate
//! (k = 32, full step budget, no early halt).
//!
//!     cargo bench --bench fig4
//!     REVOLVER_BENCH_SCALE=full cargo bench --bench fig4    # 290 steps

use revolver::config::RevolverConfig;
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::partitioners::by_name;
use revolver::util::bench::full_scale;

fn main() {
    // Smoke scale still needs enough steps for the load curves to drain
    // from the random-assignment spike (the paper's Figure 4 runs 290).
    let (n, steps) = if full_scale() { (1 << 14, 290) } else { (1 << 13, 120) };
    let g = generate_dataset(Dataset::Lj, n, 7).unwrap();
    println!(
        "=== Figure 4 — convergence on LJ surrogate (|V|={}, |E|={}, k=32, {steps} steps) ===",
        g.num_vertices(),
        g.num_edges()
    );

    std::fs::create_dir_all("results").unwrap();
    let mut finals = Vec::new();
    for algo in ["revolver", "spinner"] {
        let cfg = RevolverConfig {
            parts: 32,
            max_steps: steps,
            halt_window: u32::MAX,
            trace_every: 1,
            seed: 5,
            ..Default::default()
        };
        let out = by_name(algo, cfg).unwrap().partition(&g);
        let path = format!("results/fig4_{algo}.csv");
        std::fs::write(&path, out.trace.to_csv()).unwrap();

        // Print a decimated series (the paper's figure, as numbers).
        println!("\n{algo}: step -> local_edges, max_norm_load");
        let pts = &out.trace.points;
        for p in pts.iter().step_by((pts.len() / 12).max(1)) {
            println!(
                "  {:>4} -> {:.4}, {:.4}",
                p.step, p.local_edges, p.max_normalized_load
            );
        }
        let last = pts.last().unwrap();
        println!(
            "  final local edges {:.4}, max norm load {:.4} (wrote {path})",
            last.local_edges, last.max_normalized_load
        );
        finals.push((algo, last.local_edges, last.max_normalized_load));
    }

    // Figure 4's qualitative observations:
    let (_, rev_le, rev_mnl) = finals[0];
    let (_, spi_le, spi_mnl) = finals[1];
    println!("\npaper Fig-4 shape checks:");
    println!(
        "  Revolver local edges ≥ Spinner − 2%: {}",
        if rev_le >= spi_le - 0.02 { "reproduced" } else { "NOT reproduced" }
    );
    println!(
        "  Revolver max load visibly below Spinner's ε-cap ride: {} ({rev_mnl:.4} vs {spi_mnl:.4})",
        if rev_mnl < spi_mnl { "reproduced" } else { "NOT reproduced" }
    );
}
