//! Hot-path microbenchmarks — the §Perf working set: per-primitive
//! latencies and the end-to-end step throughput the optimization loop
//! tracks (EXPERIMENTS.md §Perf).
//!
//!     cargo bench --bench hotpath

use revolver::config::{Frontier, ProbFormat, RevolverConfig, Schedule};
use revolver::dynamic::{ChurnRecipe, IncrementalPartitioner};
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::multilevel::Refiner;
use revolver::la::roulette;
use revolver::la::signal::build_signals_into;
use revolver::la::weighted::WeightedLa;
use revolver::la::Signal;
use revolver::lp::{neighbor_histogram, neighbor_histogram_counts, normalized};
use revolver::metrics::quality;
use revolver::partitioners::revolver::ProbSlab;
use revolver::partitioners::{by_name, revolver::Revolver, spinner::Spinner, Partitioner};
use revolver::util::bench::{bench, bench_rmat, full_scale, scale_exp, validate_rows, BenchResult};
use revolver::util::json::Json;
use revolver::util::rng::Rng;

/// Every section tag a BENCH_JSON row may carry, with the numeric keys
/// each row of that section must provide — the schema
/// BENCH_hotpath.json records and `scripts/bench_hotpath.sh` harvests.
/// `validate_rows` gates the payload against this before printing.
const BENCH_SPEC: &[(&str, &[&str])] = &[
    ("schedule_rmat", &["threads", "steps", "vertices", "edges", "median_ns", "mean_ns", "min_ns"]),
    (
        "stream_rmat",
        &["parts", "vertices", "edges", "median_ns", "mean_ns", "min_ns", "local_edges",
          "max_normalized_load"],
    ),
    (
        "multilevel_rmat",
        &["parts", "vertices", "edges", "supersteps", "median_ns", "mean_ns", "min_ns",
          "local_edges", "max_normalized_load", "mean_communication_volume"],
    ),
    (
        "frontier_rmat",
        &["threads", "steps", "parts", "vertices", "edges", "median_ns", "mean_ns", "min_ns",
          "evaluated", "evaluations_saved", "local_edges", "max_normalized_load", "stamp_reads",
          "scan_steps", "worklist_steps", "chunk_reuses"],
    ),
    (
        "dynamic_rmat",
        &["epoch", "parts", "vertices", "edges", "repair_ns", "repair_steps", "seeds",
          "evaluated", "local_edges", "max_normalized_load"],
    ),
    ("hotpath_micro", &["iters", "median_ns", "mean_ns", "min_ns"]),
    (
        "frontier_collect",
        &["dense_frac", "threads", "steps", "vertices", "edges", "stamp_reads", "scan_steps",
          "worklist_steps", "chunk_reuses", "evaluated", "mean_ns"],
    ),
    (
        "probslab_rmat",
        &["threads", "steps", "parts", "vertices", "edges", "median_ns", "mean_ns", "min_ns",
          "local_edges", "max_normalized_load"],
    ),
    ("obs_overhead", &["iters", "median_ns", "mean_ns", "min_ns"]),
];

/// A `hotpath_micro` row: one isolated-primitive timing.
fn micro_row(name: &str, r: &BenchResult) -> Json {
    Json::Obj(
        [
            ("bench".to_string(), Json::Str("hotpath_micro".to_string())),
            ("name".to_string(), Json::Str(name.to_string())),
            ("iters".to_string(), Json::Num(r.iters as f64)),
            ("median_ns".to_string(), Json::Num(r.median_ns)),
            ("mean_ns".to_string(), Json::Num(r.mean_ns)),
            ("min_ns".to_string(), Json::Num(r.min_ns)),
        ]
        .into_iter()
        .collect(),
    )
}

fn main() {
    let mut rows: Vec<Json> = Vec::new();
    let n = if full_scale() { 1 << 15 } else { 1 << 13 };
    let g = generate_dataset(Dataset::Lj, n, 7).unwrap();
    let k = 32usize;
    println!(
        "=== hot-path microbenchmarks (LJ surrogate |V|={} |E|={}, k={k}) ===\n",
        g.num_vertices(),
        g.num_edges()
    );

    // Primitive 1: neighbour histogram (the CSR-bound gather).
    let labels: Vec<u32> = {
        let mut rng = Rng::new(1);
        (0..g.num_vertices()).map(|_| rng.below(k as u64) as u32).collect()
    };
    let mut hist = vec![0.0f32; k];
    let r = bench("neighbor_histogram (all vertices)", 2, 10, || {
        let mut acc = 0.0f32;
        for v in 0..g.num_vertices() as u32 {
            acc += neighbor_histogram(
                g.neighbors(v),
                g.neighbor_weights(v),
                |u| labels[u as usize],
                &mut hist,
            );
        }
        acc
    });
    println!("{r}   ({:.1}M edge-visits/s)", r.throughput(2 * g.num_edges() as u64) / 1e6);

    // Primitive 2: normalized LP score.
    let mut pi = vec![0.0f32; k];
    let loads: Vec<f32> = (0..k).map(|i| 900.0 + i as f32).collect();
    normalized::penalty_into(&loads, 64_000.0, &mut pi);
    let mut scores = vec![0.0f32; k];
    let hist2: Vec<f32> = (0..k).map(|i| i as f32).collect();
    let r = bench("score_into x 100k", 2, 10, || {
        let mut best = 0usize;
        for _ in 0..100_000 {
            best = normalized::score_into(&hist2, 42.0, &pi, &mut scores);
        }
        best
    });
    println!("{r}   ({:.1}M scores/s)", r.throughput(100_000) / 1e6);

    // Primitive 3: signal construction + weighted LA update.
    let raw: Vec<f32> = (0..k).map(|i| (i % 5) as f32).collect();
    let mut w = vec![0.0f32; k];
    let mut s = vec![Signal::Penalty; k];
    let mut p = vec![1.0 / k as f32; k];
    let r = bench("signal+weighted_update x 100k", 2, 10, || {
        for _ in 0..100_000 {
            build_signals_into(&raw, &mut w, &mut s);
            WeightedLa::update(&mut p, &w, &s, 1.0, 0.1);
        }
        p[0]
    });
    println!("{r}   ({:.1}M LA-updates/s)", r.throughput(100_000) / 1e6);

    // Primitive 4: roulette wheel, f32 and q16 wheels side by side.
    let mut rng = Rng::new(2);
    let r = bench("roulette_spin x 1M", 2, 10, || {
        let mut acc = 0usize;
        for _ in 0..1_000_000 {
            acc += roulette::spin(&p, &mut rng);
        }
        acc
    });
    println!("{r}   ({:.1}M spins/s)", r.throughput(1_000_000) / 1e6);
    rows.push(micro_row("roulette_spin_f32_1m", &r));
    let qwheel: Vec<u16> = p.iter().map(|&x| (x * 65535.0).round() as u16).collect();
    let r = bench("roulette_spin_u16 x 1M", 2, 10, || {
        let mut acc = 0usize;
        for _ in 0..1_000_000 {
            acc += roulette::spin_u16(&qwheel, &mut rng);
        }
        acc
    });
    println!("{r}   ({:.1}M spins/s)", r.throughput(1_000_000) / 1e6);
    rows.push(micro_row("roulette_spin_u16_1m", &r));

    // Primitive 5: ProbSlab row update — the LA write path in both
    // storage formats. The q16 slab pays a dequantize→update→quantize
    // round-trip per row but halves the bytes each step streams, so the
    // comparison is the memory-bound story BENCH_hotpath.json tracks.
    println!();
    let slab_rows = 4096usize;
    for (fmt_name, fmt) in [("f32", ProbFormat::F32), ("q16", ProbFormat::Q16)] {
        let mut slab = ProbSlab::new(slab_rows, k, None, fmt);
        let mut scratch = vec![0.0f32; k];
        let r = bench(&format!("probslab[{fmt_name}] update x {slab_rows} rows"), 2, 10, || {
            for v in 0..slab_rows {
                slab.update_row_mut(v, &mut scratch, &w, &s, 1.0, 0.1);
            }
            slab.row_vec(0)[0]
        });
        println!("{r}   ({:.1}M row-updates/s)", r.throughput(slab_rows as u64) / 1e6);
        rows.push(micro_row(&format!("probslab_update_{fmt_name}"), &r));
    }

    // Primitive 6: histogram + score + argmax in isolation, f32 gather
    // vs the u32 counts fast path (eq.-(4) integer weights). Same
    // vertices, same labels — the delta is pure arithmetic/layout.
    println!();
    let mut hist_u = vec![0u32; k];
    let r = bench("hist+score f32 (all vertices)", 2, 10, || {
        let mut acc = 0usize;
        for v in 0..g.num_vertices() as u32 {
            let wsum = neighbor_histogram(
                g.neighbors(v),
                g.neighbor_weights(v),
                |u| labels[u as usize],
                &mut hist,
            );
            acc += normalized::score_into(&hist, wsum, &pi, &mut scores);
        }
        acc
    });
    println!("{r}   ({:.1}M edge-visits/s)", r.throughput(2 * g.num_edges() as u64) / 1e6);
    rows.push(micro_row("hist_score_f32", &r));
    let r = bench("hist+score u32 counts (all vertices)", 2, 10, || {
        let mut acc = 0usize;
        for v in 0..g.num_vertices() as u32 {
            let cnt = neighbor_histogram_counts(
                g.neighbors(v),
                g.neighbor_weights(v),
                |u| labels[u as usize],
                &mut hist_u,
            );
            acc += normalized::score_counts_into(&hist_u, cnt, &pi, &mut scores);
        }
        acc
    });
    println!("{r}   ({:.1}M edge-visits/s)", r.throughput(2 * g.num_edges() as u64) / 1e6);
    rows.push(micro_row("hist_score_u32_counts", &r));

    // End-to-end: one full Revolver / Spinner step (the §Perf headline).
    println!();
    for (name, steps) in [("revolver", 10u32), ("spinner", 10)] {
        let cfg = RevolverConfig {
            parts: k,
            max_steps: steps,
            halt_window: u32::MAX,
            threads: 1,
            seed: 3,
            ..Default::default()
        };
        let r = match name {
            "revolver" => {
                let p = Revolver::new(cfg);
                bench(&format!("{name} {steps} steps e2e"), 1, 3, || {
                    p.partition(&g).labels.len()
                })
            }
            _ => {
                let p = Spinner::new(cfg);
                bench(&format!("{name} {steps} steps e2e"), 1, 3, || {
                    p.partition(&g).labels.len()
                })
            }
        };
        let edge_visits = steps as u64 * 2 * g.num_edges() as u64;
        println!("{r}   ({:.2}M edge-visits/s)", r.throughput(edge_visits) / 1e6);
    }

    // Scheduler comparison: vertex- vs degree-balanced chunking on a
    // power-law R-MAT graph. Vertex-balanced chunks hand the hub-heavy
    // prefix to one worker; every barrier then waits on it. The JSON
    // line at the end feeds the BENCH trajectory.
    let rg = bench_rmat(scale_exp(15, 13));
    println!(
        "\n=== scheduler: vertex vs degree chunks (R-MAT |V|={} |E|={}, k={k}) ===\n",
        rg.num_vertices(),
        rg.num_edges()
    );
    let steps = 5u32;
    for threads in [1usize, 2, 4, 8] {
        for schedule in [Schedule::Vertex, Schedule::Degree] {
            let cfg = RevolverConfig {
                parts: k,
                max_steps: steps,
                halt_window: u32::MAX,
                threads,
                schedule,
                seed: 3,
                ..Default::default()
            };
            let p = Revolver::new(cfg);
            let name = format!("revolver {steps} steps, t={threads}, {schedule:?}");
            let r = bench(&name, 1, 3, || p.partition(&rg).labels.len());
            println!("{r}");
            rows.push(Json::Obj(
                [
                    ("bench".to_string(), Json::Str("schedule_rmat".to_string())),
                    ("schedule".to_string(), Json::Str(format!("{schedule:?}").to_lowercase())),
                    ("threads".to_string(), Json::Num(threads as f64)),
                    ("steps".to_string(), Json::Num(steps as f64)),
                    ("vertices".to_string(), Json::Num(rg.num_vertices() as f64)),
                    ("edges".to_string(), Json::Num(rg.num_edges() as f64)),
                    ("median_ns".to_string(), Json::Num(r.median_ns)),
                    ("mean_ns".to_string(), Json::Num(r.mean_ns)),
                    ("min_ns".to_string(), Json::Num(r.min_ns)),
                ]
                .into_iter()
                .collect(),
            ));
        }
    }
    // Streaming partitioners: one-pass (ldg/fennel) and restreaming
    // throughput + quality vs the hash floor on power-law R-MAT graphs
    // across scales. Streaming is the cheap-baseline family the paper
    // compares against; the JSON rows feed the BENCH trajectory.
    let k8 = 8usize;
    let exps: &[u32] = if full_scale() { &[14, 16, 18] } else { &[14] };
    for &e in exps {
        let sg = bench_rmat(e);
        println!(
            "\n=== streaming: ldg / fennel / restream vs hash (R-MAT |V|={} |E|={}, k={k8}) ===\n",
            sg.num_vertices(),
            sg.num_edges()
        );
        for algo in ["ldg", "fennel", "restream", "hash"] {
            let cfg = RevolverConfig { parts: k8, seed: 3, ..Default::default() };
            let p = by_name(algo, cfg).unwrap();
            let labels = p.partition(&sg).labels;
            let q = quality::evaluate(&sg, &labels, k8);
            let r = bench(&format!("{algo:>8} 2^{e}"), 1, 3, || p.partition(&sg).labels.len());
            println!(
                "{r}   ({:.1}M edges/s, local={:.4}, mnl={:.3})",
                r.throughput(sg.num_edges() as u64) / 1e6,
                q.local_edges,
                q.max_normalized_load
            );
            rows.push(Json::Obj(
                [
                    ("bench".to_string(), Json::Str("stream_rmat".to_string())),
                    ("algorithm".to_string(), Json::Str(algo.to_string())),
                    ("parts".to_string(), Json::Num(k8 as f64)),
                    ("vertices".to_string(), Json::Num(sg.num_vertices() as f64)),
                    ("edges".to_string(), Json::Num(sg.num_edges() as f64)),
                    ("median_ns".to_string(), Json::Num(r.median_ns)),
                    ("mean_ns".to_string(), Json::Num(r.mean_ns)),
                    ("min_ns".to_string(), Json::Num(r.min_ns)),
                    ("local_edges".to_string(), Json::Num(q.local_edges)),
                    ("max_normalized_load".to_string(), Json::Num(q.max_normalized_load)),
                ]
                .into_iter()
                .collect(),
            ));
        }
    }

    // Multilevel V-cycle vs flat Spinner at the same total superstep
    // budget, on power-law R-MAT graphs across scales. The V-cycle
    // spends most of its supersteps on levels a fraction of |V|, so at
    // equal budget it should dominate on locality while the rebalance
    // pass pins the ε envelope; the JSON rows feed the BENCH trajectory
    // alongside stream_rmat.
    for &e in exps {
        let mg = bench_rmat(e);
        println!(
            "\n=== multilevel: V-cycle vs spinner at equal budget (R-MAT |V|={} |E|={}, k={k8}) ===\n",
            mg.num_vertices(),
            mg.num_edges()
        );
        let ml_cfg = RevolverConfig { parts: k8, seed: 3, ..Default::default() };
        let ml = by_name("multilevel", ml_cfg).unwrap();
        let ml_out = ml.partition(&mg);
        let budget = ml_out.trace.steps().max(1);
        let q_ml = quality::evaluate(&mg, &ml_out.labels, k8);

        let sp_cfg = RevolverConfig {
            parts: k8,
            seed: 3,
            max_steps: budget,
            halt_window: u32::MAX,
            ..Default::default()
        };
        let sp = by_name("spinner", sp_cfg).unwrap();
        let sp_out = sp.partition(&mg);
        let q_sp = quality::evaluate(&mg, &sp_out.labels, k8);

        for (algo, p, q) in [
            ("multilevel", &ml, &q_ml),
            ("spinner", &sp, &q_sp),
        ] {
            let r = bench(&format!("{algo:>10} 2^{e} ({budget} supersteps)"), 1, 3, || {
                p.partition(&mg).labels.len()
            });
            println!(
                "{r}   (local={:.4}, mnl={:.3}, cv={:.3})",
                q.local_edges, q.max_normalized_load, q.mean_communication_volume
            );
            rows.push(Json::Obj(
                [
                    ("bench".to_string(), Json::Str("multilevel_rmat".to_string())),
                    ("algorithm".to_string(), Json::Str(algo.to_string())),
                    ("parts".to_string(), Json::Num(k8 as f64)),
                    ("vertices".to_string(), Json::Num(mg.num_vertices() as f64)),
                    ("edges".to_string(), Json::Num(mg.num_edges() as f64)),
                    ("supersteps".to_string(), Json::Num(budget as f64)),
                    ("median_ns".to_string(), Json::Num(r.median_ns)),
                    ("mean_ns".to_string(), Json::Num(r.mean_ns)),
                    ("min_ns".to_string(), Json::Num(r.min_ns)),
                    ("local_edges".to_string(), Json::Num(q.local_edges)),
                    ("max_normalized_load".to_string(), Json::Num(q.max_normalized_load)),
                    (
                        "mean_communication_volume".to_string(),
                        Json::Num(q.mean_communication_volume),
                    ),
                ]
                .into_iter()
                .collect(),
            ));
        }
    }

    // Active-set execution: Revolver with the frontier on vs off, same
    // seed, across scales and thread counts. The interesting number is
    // *total vertex-evaluations saved* — wall clock follows it once the
    // frontier shrinks below |V| — so each row carries `evaluated`
    // alongside the timing stats (full sweep = steps × |V|).
    let fsteps = 10u32;
    for &e in exps {
        let fg = bench_rmat(e);
        let full_evals = fsteps as u64 * fg.num_vertices() as u64;
        println!(
            "\n=== frontier: active-set vs full sweeps (R-MAT |V|={} |E|={}, k={k8}) ===\n",
            fg.num_vertices(),
            fg.num_edges()
        );
        for threads in [1usize, 2, 4, 8] {
            for frontier in [Frontier::Off, Frontier::On] {
                let cfg = RevolverConfig {
                    parts: k8,
                    max_steps: fsteps,
                    halt_window: u32::MAX,
                    threads,
                    frontier,
                    seed: 3,
                    ..Default::default()
                };
                let p = Revolver::new(cfg);
                let out = p.partition(&fg);
                let evaluated = out.trace.total_evaluated;
                let saved = full_evals.saturating_sub(evaluated);
                let q = quality::evaluate(&fg, &out.labels, k8);
                let name = format!(
                    "revolver {fsteps} steps 2^{e}, t={threads}, frontier={frontier:?}"
                );
                let r = bench(&name, 1, 3, || p.partition(&fg).labels.len());
                println!(
                    "{r}   (evals={evaluated}, saved={:.1}%, local={:.4}, mnl={:.3})",
                    100.0 * saved as f64 / full_evals as f64,
                    q.local_edges,
                    q.max_normalized_load
                );
                rows.push(Json::Obj(
                    [
                        ("bench".to_string(), Json::Str("frontier_rmat".to_string())),
                        (
                            "frontier".to_string(),
                            Json::Str(format!("{frontier:?}").to_lowercase()),
                        ),
                        ("threads".to_string(), Json::Num(threads as f64)),
                        ("steps".to_string(), Json::Num(fsteps as f64)),
                        ("parts".to_string(), Json::Num(k8 as f64)),
                        ("vertices".to_string(), Json::Num(fg.num_vertices() as f64)),
                        ("edges".to_string(), Json::Num(fg.num_edges() as f64)),
                        ("median_ns".to_string(), Json::Num(r.median_ns)),
                        ("mean_ns".to_string(), Json::Num(r.mean_ns)),
                        ("min_ns".to_string(), Json::Num(r.min_ns)),
                        ("evaluated".to_string(), Json::Num(evaluated as f64)),
                        ("evaluations_saved".to_string(), Json::Num(saved as f64)),
                        ("local_edges".to_string(), Json::Num(q.local_edges)),
                        (
                            "max_normalized_load".to_string(),
                            Json::Num(q.max_normalized_load),
                        ),
                        ("stamp_reads".to_string(), Json::Num(out.trace.stamp_reads as f64)),
                        ("scan_steps".to_string(), Json::Num(out.trace.scan_steps as f64)),
                        (
                            "worklist_steps".to_string(),
                            Json::Num(out.trace.worklist_steps as f64),
                        ),
                        ("chunk_reuses".to_string(), Json::Num(out.trace.chunk_reuses as f64)),
                    ]
                    .into_iter()
                    .collect(),
                ));
            }
        }
    }

    // Dynamic subsystem: per-epoch incremental repair under 2% uniform
    // edge churn — the number that matters is evaluated vertex-steps
    // per epoch (the frontier-localized region), with wall time per
    // epoch alongside. Epochs mutate state, so each is timed once
    // (Stopwatch) rather than through the repeat harness.
    for &e in exps {
        let dg = bench_rmat(e);
        let n = dg.num_vertices();
        println!(
            "\n=== dynamic: incremental repair under churn (R-MAT |V|={} |E|={}, k={k8}) ===\n",
            n,
            dg.num_edges()
        );
        let cfg = RevolverConfig {
            parts: k8,
            max_steps: 40,
            threads: 1,
            seed: 3,
            repair_steps: 5,
            ..Default::default()
        };
        let mut inc = IncrementalPartitioner::new(dg, cfg, Refiner::Spinner).unwrap();
        let recipe = ChurnRecipe::Uniform { frac: 0.02 };
        let epochs = if full_scale() { 5u64 } else { 3 };
        for epoch in 0..epochs {
            let batch = recipe.generate(inc.current(), 900 + epoch);
            let sw = revolver::util::Stopwatch::start();
            let stats = inc.epoch(&batch).unwrap();
            let repair_ns = sw.elapsed_s() * 1e9;
            let q = quality::evaluate(inc.current(), inc.labels(), k8);
            println!(
                "epoch {epoch} 2^{e}: {:.2}ms  seeds={} evaluated={} ({:.1}% of full sweep) local={:.4} mnl={:.3}",
                repair_ns / 1e6,
                stats.seeds,
                stats.evaluated,
                100.0 * stats.evaluated as f64
                    / (n as f64 * stats.repair_steps.max(1) as f64),
                q.local_edges,
                q.max_normalized_load
            );
            rows.push(Json::Obj(
                [
                    ("bench".to_string(), Json::Str("dynamic_rmat".to_string())),
                    ("epoch".to_string(), Json::Num(epoch as f64)),
                    ("churn".to_string(), Json::Str("uniform:0.02".to_string())),
                    ("parts".to_string(), Json::Num(k8 as f64)),
                    ("vertices".to_string(), Json::Num(n as f64)),
                    ("edges".to_string(), Json::Num(inc.current().num_edges() as f64)),
                    ("repair_ns".to_string(), Json::Num(repair_ns)),
                    ("repair_steps".to_string(), Json::Num(stats.repair_steps as f64)),
                    ("seeds".to_string(), Json::Num(stats.seeds as f64)),
                    ("evaluated".to_string(), Json::Num(stats.evaluated as f64)),
                    ("local_edges".to_string(), Json::Num(q.local_edges)),
                    ("max_normalized_load".to_string(), Json::Num(q.max_normalized_load)),
                ]
                .into_iter()
                .collect(),
            ));
        }
    }

    // Frontier collection in isolation: the same active-set run under
    // the three collector regimes (dense scan / worklist / hybrid).
    // Labels are bit-identical across rows (hotpath_parity.rs proves
    // it), so the stamp_reads / scan_steps / worklist_steps deltas at
    // equal mean_ns isolate the scheduling cost — this is where the
    // "≥5× fewer stamp reads" acceptance row comes from.
    for &e in exps {
        let cg = bench_rmat(e);
        println!(
            "\n=== frontier collect: scan vs worklist vs hybrid (R-MAT |V|={} |E|={}, k={k8}) ===\n",
            cg.num_vertices(),
            cg.num_edges()
        );
        for frac in [0.0f64, 1.0, 0.25] {
            let cfg = RevolverConfig {
                parts: k8,
                max_steps: fsteps,
                halt_window: u32::MAX,
                threads: 1,
                frontier: Frontier::On,
                frontier_dense_frac: frac,
                seed: 3,
                ..Default::default()
            };
            let p = Revolver::new(cfg);
            let out = p.partition(&cg);
            let name = format!("collect 2^{e} dense_frac={frac}");
            let r = bench(&name, 1, 3, || p.partition(&cg).labels.len());
            println!(
                "{r}   (stamp_reads={}, scan={}, worklist={}, chunk_reuses={})",
                out.trace.stamp_reads,
                out.trace.scan_steps,
                out.trace.worklist_steps,
                out.trace.chunk_reuses
            );
            rows.push(Json::Obj(
                [
                    ("bench".to_string(), Json::Str("frontier_collect".to_string())),
                    ("dense_frac".to_string(), Json::Num(frac)),
                    ("threads".to_string(), Json::Num(1.0)),
                    ("steps".to_string(), Json::Num(fsteps as f64)),
                    ("vertices".to_string(), Json::Num(cg.num_vertices() as f64)),
                    ("edges".to_string(), Json::Num(cg.num_edges() as f64)),
                    ("stamp_reads".to_string(), Json::Num(out.trace.stamp_reads as f64)),
                    ("scan_steps".to_string(), Json::Num(out.trace.scan_steps as f64)),
                    ("worklist_steps".to_string(), Json::Num(out.trace.worklist_steps as f64)),
                    ("chunk_reuses".to_string(), Json::Num(out.trace.chunk_reuses as f64)),
                    ("evaluated".to_string(), Json::Num(out.trace.total_evaluated as f64)),
                    ("mean_ns".to_string(), Json::Num(r.mean_ns)),
                ]
                .into_iter()
                .collect(),
            ));
        }
    }

    // Quantized LA storage end-to-end: the same frontier run with f32
    // vs q16 slab rows. Different trajectories (the q16 wheel consumes
    // the RNG differently), so each row carries its own quality numbers
    // — the acceptance check is the time ratio *and* the q16 quality
    // staying inside the envelope hotpath_parity.rs enforces.
    for &e in exps {
        let pg = bench_rmat(e);
        println!(
            "\n=== probslab: f32 vs q16 rows, frontier on (R-MAT |V|={} |E|={}, k={k8}) ===\n",
            pg.num_vertices(),
            pg.num_edges()
        );
        for (fmt_name, fmt) in [("f32", ProbFormat::F32), ("q16", ProbFormat::Q16)] {
            let cfg = RevolverConfig {
                parts: k8,
                max_steps: fsteps,
                halt_window: u32::MAX,
                threads: 1,
                frontier: Frontier::On,
                prob_format: fmt,
                seed: 3,
                ..Default::default()
            };
            let p = Revolver::new(cfg);
            let out = p.partition(&pg);
            let q = quality::evaluate(&pg, &out.labels, k8);
            let r = bench(&format!("revolver 2^{e} prob_format={fmt_name}"), 1, 3, || {
                p.partition(&pg).labels.len()
            });
            println!(
                "{r}   (local={:.4}, mnl={:.3})",
                q.local_edges, q.max_normalized_load
            );
            rows.push(Json::Obj(
                [
                    ("bench".to_string(), Json::Str("probslab_rmat".to_string())),
                    ("prob_format".to_string(), Json::Str(fmt_name.to_string())),
                    ("threads".to_string(), Json::Num(1.0)),
                    ("steps".to_string(), Json::Num(fsteps as f64)),
                    ("parts".to_string(), Json::Num(k8 as f64)),
                    ("vertices".to_string(), Json::Num(pg.num_vertices() as f64)),
                    ("edges".to_string(), Json::Num(pg.num_edges() as f64)),
                    ("median_ns".to_string(), Json::Num(r.median_ns)),
                    ("mean_ns".to_string(), Json::Num(r.mean_ns)),
                    ("min_ns".to_string(), Json::Num(r.min_ns)),
                    ("local_edges".to_string(), Json::Num(q.local_edges)),
                    ("max_normalized_load".to_string(), Json::Num(q.max_normalized_load)),
                ]
                .into_iter()
                .collect(),
            ));
        }
    }

    // Observability overhead guard: the same engine run with recording
    // disabled, with the no-op recorder (pure dispatch cost), and with
    // a full RunRecorder retaining everything. The acceptance claim is
    // that disabled ≈ noop ≈ recorder within noise — instrumentation
    // must never show up in the step loop's profile.
    {
        let og = bench_rmat(scale_exp(14, 12));
        println!(
            "\n=== obs overhead: disabled vs noop vs recorder (R-MAT |V|={} |E|={}, k={k8}) ===\n",
            og.num_vertices(),
            og.num_edges()
        );
        let cfg = RevolverConfig {
            parts: k8,
            max_steps: 5,
            halt_window: u32::MAX,
            threads: 1,
            seed: 3,
            ..Default::default()
        };
        let p = Revolver::new(cfg);
        for mode in ["disabled", "noop", "recorder"] {
            match mode {
                "noop" => revolver::obs::install(std::sync::Arc::new(revolver::obs::NoopRecorder)),
                "recorder" => revolver::obs::install(std::sync::Arc::new(
                    revolver::obs::RunRecorder::new(),
                )),
                _ => {}
            }
            let r = bench(&format!("revolver 5 steps obs={mode}"), 1, 3, || {
                p.partition(&og).labels.len()
            });
            revolver::obs::uninstall();
            println!("{r}");
            let mut row = micro_row(mode, &r);
            if let Json::Obj(m) = &mut row {
                m.insert("bench".to_string(), Json::Str("obs_overhead".to_string()));
                m.insert("mode".to_string(), Json::Str(mode.to_string()));
            }
            rows.push(row);
        }

        // diag_overhead: the learning-dynamics observatory priced the
        // same way — a full RunRecorder installed both times, the same
        // 5-step run with per-step tracing, `--diag` off vs on. The
        // delta is the flow matrix, the frontier decisiveness reads,
        // the oscillation scan, and the partition samples together.
        for (mode, diag) in [("diag_off", false), ("diag_on", true)] {
            let cfg = RevolverConfig {
                parts: k8,
                max_steps: 5,
                halt_window: u32::MAX,
                threads: 1,
                seed: 3,
                trace_every: 1,
                diag,
                ..Default::default()
            };
            let p = Revolver::new(cfg);
            revolver::obs::install(std::sync::Arc::new(revolver::obs::RunRecorder::new()));
            let r = bench(&format!("revolver 5 steps {mode}"), 1, 3, || {
                p.partition(&og).labels.len()
            });
            revolver::obs::uninstall();
            println!("{r}");
            let mut row = micro_row(mode, &r);
            if let Json::Obj(m) = &mut row {
                m.insert("bench".to_string(), Json::Str("obs_overhead".to_string()));
                m.insert("mode".to_string(), Json::Str(mode.to_string()));
            }
            rows.push(row);
        }

        // obs_http: `/metrics` scrape latency under write load — a
        // populated recorder served live while writer threads keep
        // hammering the registry, timed end to end through a real TCP
        // GET (connection setup + render + transfer).
        {
            use revolver::obs::{httpd, Recorder as _, RunRecorder};
            use std::sync::atomic::{AtomicBool, Ordering};
            use std::sync::Arc;
            let rec = Arc::new(RunRecorder::new());
            revolver::obs::install(rec.clone());
            let _ = p.partition(&og); // populate engine metrics + spans
            revolver::obs::uninstall();
            let srv = revolver::obs::http::MetricsServer::start("127.0.0.1:0", rec.clone())
                .expect("bind loopback for the obs_http bench");
            let addr = srv.local_addr();
            let stop = Arc::new(AtomicBool::new(false));
            let writers: Vec<_> = (0..4)
                .map(|_| {
                    let rec = rec.clone();
                    let stop = stop.clone();
                    std::thread::spawn(move || {
                        let mut i = 0u64;
                        while !stop.load(Ordering::Relaxed) {
                            rec.counter_add("bench_scrape_load", 1);
                            rec.observe("bench_scrape_hist", i % 4096);
                            i += 1;
                        }
                    })
                })
                .collect();
            let r = bench("GET /metrics under write load", 5, 50, || {
                let timeout = std::time::Duration::from_secs(5);
                let (status, _, body) =
                    httpd::get(addr, "/metrics", timeout).expect("live scrape must answer");
                assert_eq!(status, 200);
                body.len()
            });
            stop.store(true, Ordering::Relaxed);
            for w in writers {
                w.join().unwrap();
            }
            drop(srv);
            println!("{r}");
            let mut row = micro_row("obs_http_scrape", &r);
            if let Json::Obj(m) = &mut row {
                m.insert("bench".to_string(), Json::Str("obs_overhead".to_string()));
                m.insert("mode".to_string(), Json::Str("obs_http".to_string()));
            }
            rows.push(row);
        }
    }

    // Schema gate: a renamed key or unknown section dies here rather
    // than producing unmergeable BENCH_hotpath.json history rows.
    let payload = Json::Arr(rows);
    match validate_rows(&payload, BENCH_SPEC) {
        Ok(count) => println!("\n({count} BENCH_JSON rows validated)"),
        Err(e) => panic!("BENCH_JSON schema violation: {e}"),
    }
    println!("\nBENCH_JSON {}", payload.to_string());
}
