//! E1 — Table I reproduction: dataset statistics (|V|, |E|, density,
//! Pearson's 1st skewness) for the nine surrogate graphs, side by side
//! with the paper's reference values, plus generator throughput.
//!
//!     cargo bench --bench table1
//!     REVOLVER_BENCH_SCALE=full cargo bench --bench table1

use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::graph::stats;
use revolver::util::bench::{bench, full_scale};
use revolver::util::with_commas;

fn main() {
    let n = if full_scale() { 1 << 16 } else { 1 << 13 };
    println!("=== Table I — surrogate dataset statistics (scale: {n} vertices) ===\n");
    println!(
        "{:<6} | {:>10} {:>12} {:>9} {:>7} | paper: {:>8} {:>8} {:>6} {:>6} | class match",
        "graph", "|V|", "|E|", "D(e-5)", "skew", "|V|", "|E|", "D(e-5)", "skew"
    );

    let mut matches = 0;
    for ds in Dataset::ALL {
        let g = generate_dataset(ds, n, 7).unwrap();
        let s = stats::compute(&g);
        let p = ds.paper_stats();
        let ours = stats::classify_skew(s.skewness);
        let theirs = stats::classify_skew(p.skew);
        let class_ok = ours == theirs;
        matches += class_ok as u32;
        println!(
            "{:<6} | {:>10} {:>12} {:>9.3} {:>7.3} | {:>8} {:>8} {:>6.2} {:>6.2} | {}",
            ds.name(),
            with_commas(s.vertices as u64),
            with_commas(s.edges as u64),
            s.density * 1e5,
            s.skewness,
            format!("{:.2}M", p.vertices / 1e6),
            format!("{:.1}M", p.edges / 1e6),
            p.density_e5,
            p.skew,
            if class_ok { "yes" } else { "NO" },
        );
    }
    println!("\nskew-class agreement: {matches}/9 (density is scale-dependent; skew class is the fidelity criterion, DESIGN.md §4)");

    println!("\n=== generator throughput ===");
    for ds in [Dataset::Lj, Dataset::Usa, Dataset::Hlwd] {
        let r = bench(&format!("generate {} ({} vertices)", ds.name(), n), 1, 3, || {
            generate_dataset(ds, n, 7).unwrap().num_edges()
        });
        let edges = generate_dataset(ds, n, 7).unwrap().num_edges();
        println!("{r}   ({:.1}M edges/s)", r.throughput(edges as u64) / 1e6);
    }
}
