//! E2 — Figure 3 reproduction: average local edges (bars) and max
//! normalized load (lines) for {Revolver, Spinner, Hash, Range} across
//! all nine graphs and a sweep of partition counts.
//!
//! Smoke scale (default): 3 partition counts, 1 run, 4k vertices —
//! finishes in a few minutes on one core. Full scale
//! (REVOLVER_BENCH_SCALE=full): the paper's 9 partition counts
//! {2,...,256}, 10 runs averaged, 16k vertices.
//!
//! Output: the per-graph series (same rows the paper plots) on stdout
//! and results/fig3_<scale>.csv + .json.

use revolver::config::RevolverConfig;
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::metrics::quality;
use revolver::metrics::report::{Report, ResultRow};
use revolver::partitioners::by_name;
use revolver::util::bench::full_scale;
use revolver::util::Stopwatch;

fn main() {
    let full = full_scale();
    let (n, parts, runs): (usize, &[usize], u32) = if full {
        (1 << 14, &[2, 4, 8, 16, 32, 64, 128, 192, 256], 10)
    } else {
        (1 << 12, &[2, 8, 32], 1)
    };
    println!(
        "=== Figure 3 sweep (scale: {} vertices, k in {parts:?}, {runs} run(s)) ===",
        n
    );

    let mut report = Report::new();
    for ds in Dataset::ALL {
        let g = generate_dataset(ds, n, 7).unwrap();
        eprintln!("[fig3] {} |V|={} |E|={}", ds.name(), g.num_vertices(), g.num_edges());
        for algo in ["revolver", "spinner", "hash", "range"] {
            for &k in parts {
                let sw = Stopwatch::start();
                let mut le = 0.0;
                let mut mnl = 0.0;
                let mut steps = 0u32;
                for run in 0..runs {
                    let cfg = RevolverConfig {
                        parts: k,
                        seed: 42 + run as u64,
                        ..Default::default()
                    };
                    let out = by_name(algo, cfg).unwrap().partition(&g);
                    let q = quality::evaluate(&g, &out.labels, k);
                    le += q.local_edges;
                    mnl += q.max_normalized_load;
                    steps += out.trace.steps();
                }
                report.push(ResultRow {
                    graph: ds.name().to_string(),
                    algorithm: algo.to_string(),
                    parts: k as u32,
                    local_edges: le / runs as f64,
                    max_normalized_load: mnl / runs as f64,
                    steps: steps / runs,
                    wall_time_s: sw.elapsed_s() / runs as f64,
                    runs,
                });
            }
        }
    }

    print!("{}", report.to_table());

    // The paper's headline claims, checked over the whole sweep:
    let rows = report.rows();
    let rev_wins_balance = rows
        .iter()
        .filter(|r| r.algorithm == "revolver")
        .all(|r| {
            rows.iter()
                .filter(|o| o.graph == r.graph && o.parts == r.parts && o.algorithm != "revolver")
                .all(|o| r.max_normalized_load <= o.max_normalized_load + 0.10)
        });
    println!("Revolver best-or-tied max normalized load everywhere: {rev_wins_balance}");

    let stem = if full { "fig3_full" } else { "fig3_smoke" };
    report.write_files(std::path::Path::new("results"), stem).unwrap();
    println!("wrote results/{stem}.csv and .json");
}
