//! E4 — §V-H.2 ablation: asynchronous vs synchronous Revolver.
//!
//! The paper attributes Revolver's balance advantage to the async
//! model's progressively-exchanged loads (up to 28× better max load on
//! EU vs synchronous Spinner). This ablation isolates the execution
//! model with everything else fixed.
//!
//!     cargo bench --bench ablation_async

use revolver::config::{ExecutionModel, RevolverConfig};
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::metrics::quality;
use revolver::partitioners::by_name;
use revolver::util::bench::scale_exp;

fn main() {
    let n = 1usize << scale_exp(14, 12);
    println!("=== E4 — async vs sync Revolver (|V|≈{n}) ===\n");
    println!(
        "{:<6} {:>4} | {:>21} | {:>21} | async wins-or-ties balance",
        "graph", "k", "async le / mnl", "sync le / mnl"
    );

    let mut wins = 0;
    let mut total = 0;
    for ds in [Dataset::Lj, Dataset::Ok, Dataset::Eu, Dataset::So] {
        let g = generate_dataset(ds, n, 7).unwrap();
        for k in [8usize, 32] {
            // Average 3 seeds: single runs are dominated by seed noise
            // once both variants reach the ε cap.
            let mut res = Vec::new();
            for exec in [ExecutionModel::Asynchronous, ExecutionModel::Synchronous] {
                let (mut le, mut mnl) = (0.0, 0.0);
                for seed in 0..3u64 {
                    let cfg = RevolverConfig {
                        parts: k,
                        execution: exec,
                        seed: 3 + seed,
                        ..Default::default()
                    };
                    let out = by_name("revolver", cfg).unwrap().partition(&g);
                    let q = quality::evaluate(&g, &out.labels, k);
                    le += q.local_edges / 3.0;
                    mnl += q.max_normalized_load / 3.0;
                }
                res.push(quality::Quality {
                    local_edges: le,
                    max_normalized_load: mnl,
                    max_normalized_edge_load: 0.0,  // unused by this ablation
                    mean_communication_volume: 0.0, // unused by this ablation
                });
            }
            let win = res[0].max_normalized_load <= res[1].max_normalized_load + 0.02;
            wins += win as u32;
            total += 1;
            println!(
                "{:<6} {:>4} | {:>9.4} / {:>9.4} | {:>9.4} / {:>9.4} | {}",
                ds.name(),
                k,
                res[0].local_edges,
                res[0].max_normalized_load,
                res[1].local_edges,
                res[1].max_normalized_load,
                if win { "yes" } else { "no" }
            );
        }
    }
    println!("\nasync balance wins-or-ties: {wins}/{total} (paper: async always wins or ties; 3-seed averages)");
}
