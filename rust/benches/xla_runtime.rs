//! E7 — L1/L2 runtime benchmarks: latency of the AOT-compiled XLA
//! kernels (score, la_update, fused step) vs the native Rust
//! implementations at the same batch shape, plus end-to-end Revolver
//! step throughput under both engines.
//!
//! Requires `make artifacts`.
//!
//!     cargo bench --bench xla_runtime

use revolver::config::{Engine, RevolverConfig};
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::la::signal::build_signals_into;
use revolver::la::weighted::WeightedLa;
use revolver::la::Signal;
use revolver::lp::normalized;
use revolver::partitioners::{revolver::Revolver, Partitioner};
use revolver::runtime::XlaStepEngine;
use revolver::util::bench::bench;
use revolver::util::rng::Rng;

const BATCH: usize = 256;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        return;
    }

    println!("=== E7 — XLA kernel latency vs native (batch {BATCH}) ===\n");
    for k in [8usize, 32] {
        let mut eng = XlaStepEngine::load("artifacts", BATCH, k, 1.0, 0.1).unwrap();
        let mut rng = Rng::new(1);
        let hist: Vec<f32> = (0..BATCH * k).map(|_| rng.next_f32() * 5.0).collect();
        let wsum: Vec<f32> = (0..BATCH).map(|_| 8.0).collect();
        let loads: Vec<f32> = (0..k).map(|_| rng.next_f32() * 900.0).collect();
        let probs = vec![1.0 / k as f32; BATCH * k];
        let raw_w: Vec<f32> = (0..BATCH * k).map(|_| rng.next_f32()).collect();

        let r = bench(&format!("xla score       k={k}"), 3, 30, || {
            eng.score(&hist, &wsum, &loads, 1000.0).unwrap()
        });
        println!("{r}   ({:.1}M vertex-scores/s)", r.throughput(BATCH as u64) / 1e6);

        let r = bench(&format!("xla la_update   k={k}"), 3, 30, || {
            eng.la_update(&probs, &raw_w).unwrap()
        });
        println!("{r}   ({:.1}M LA-updates/s)", r.throughput(BATCH as u64) / 1e6);

        // Native equivalents at identical batch shape.
        let mut pi = vec![0.0f32; k];
        let mut scores = vec![0.0f32; k];
        let r = bench(&format!("native score    k={k}"), 3, 30, || {
            normalized::penalty_into(&loads, 1000.0, &mut pi);
            let mut acc = 0.0f32;
            for i in 0..BATCH {
                normalized::score_into(&hist[i * k..(i + 1) * k], wsum[i], &pi, &mut scores);
                acc += scores[0];
            }
            acc
        });
        println!("{r}");

        let mut w_norm = vec![0.0f32; k];
        let mut sigs = vec![Signal::Penalty; k];
        let r = bench(&format!("native la_update k={k}"), 3, 30, || {
            let mut p = probs.clone();
            for i in 0..BATCH {
                build_signals_into(&raw_w[i * k..(i + 1) * k], &mut w_norm, &mut sigs);
                WeightedLa::update(&mut p[i * k..(i + 1) * k], &w_norm, &sigs, 1.0, 0.1);
            }
            p
        });
        println!("{r}\n");
    }

    println!("=== end-to-end Revolver step throughput, native vs xla engine ===\n");
    let g = generate_dataset(Dataset::Lj, 1 << 12, 7).unwrap();
    for engine in [Engine::Native, Engine::Xla] {
        let cfg = RevolverConfig {
            parts: 8,
            engine,
            max_steps: 10,
            halt_window: u32::MAX,
            threads: 1,
            seed: 9,
            ..Default::default()
        };
        let rev = Revolver::new(cfg);
        let r = bench(&format!("revolver 10 steps ({engine:?})"), 1, 3, || {
            rev.partition(&g).labels.len()
        });
        let edge_visits = 10 * g.num_edges() as u64;
        println!("{r}   ({:.2}M edge-visits/s)", r.throughput(edge_visits) / 1e6);
    }
    println!("\n(the native engine wins on CPU: PJRT buffer round-trips dominate at");
    println!(" this batch size — the XLA path exists to validate the three-layer");
    println!(" architecture and to model the TPU deployment, see DESIGN.md §Perf)");
}
