//! E5 — §IV-A / §V-I ablation: weighted vs classic learning automata.
//!
//! The paper motivates the weighted LA by the curse of dimensionality:
//! with many actions, the classic single-reward update concentrates too
//! slowly / too harshly. This ablation swaps only the LA update rule and
//! sweeps k, measuring final quality.
//!
//!     cargo bench --bench ablation_weighted_la

use revolver::config::RevolverConfig;
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::metrics::quality;
use revolver::partitioners::by_name;
use revolver::util::bench::full_scale;

fn main() {
    let n = if full_scale() { 1 << 14 } else { 1 << 12 };
    let parts: &[usize] =
        if full_scale() { &[4, 16, 64, 128, 256] } else { &[4, 32, 128] };
    let g = generate_dataset(Dataset::Lj, n, 7).unwrap();
    println!(
        "=== E5 — weighted vs classic LA on LJ surrogate (|V|={}, |E|={}) ===\n",
        g.num_vertices(),
        g.num_edges()
    );
    println!(
        "{:>4} | {:>21} | {:>21} | weighted wins le",
        "k", "weighted le / mnl", "classic le / mnl"
    );

    let mut wins = 0;
    for &k in parts {
        let mut res = Vec::new();
        for classic in [false, true] {
            let cfg = RevolverConfig {
                parts: k,
                classic_la: classic,
                seed: 3,
                ..Default::default()
            };
            let out = by_name("revolver", cfg).unwrap().partition(&g);
            res.push(quality::evaluate(&g, &out.labels, k));
        }
        let win = res[0].local_edges >= res[1].local_edges - 1e-6;
        wins += win as u32;
        println!(
            "{:>4} | {:>9.4} / {:>9.4} | {:>9.4} / {:>9.4} | {}",
            k,
            res[0].local_edges,
            res[0].max_normalized_load,
            res[1].local_edges,
            res[1].max_normalized_load,
            if win { "yes" } else { "no" }
        );
    }
    println!(
        "\nweighted LA local-edges wins: {wins}/{} (paper §V-I: the gap should widen with k)",
        parts.len()
    );
}
